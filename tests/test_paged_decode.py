"""Fused table-consuming paged flash decode (PR 6 acceptance).

Three pin families:

  * parity — the fused sweep (blocked reference AND scalar-prefetch
    Pallas kernel under interpret) matches gather-then-dense-decode at
    the kernel level, and the fused engine default is token-exact
    against both the gather ablation and the sequential scalar-pos path
    for ALL FIVE families, through slot recycling and pool growth;
  * block-table invariants (hypothesis when installed, seeded sweep
    otherwise) — random admit/retire/grow keeps live tables pairwise
    disjoint, the column-major ``pid -> (pid % slots, (pid//slots)*bs)``
    grid mapping round-trips, and scatter writes through retired
    (unmapped) table entries drop without touching any other location;
  * executed-plan pins — the router-resolved ``block_s`` + table
    geometry reach the kernel call the engine actually RUNS (spy),
    changing the plan changes the lowered step while the logits stay
    fixed, and the unpaged step lowers byte-identical to the pre-PR
    decode path.
"""

import dataclasses
import random

import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_config
from repro.serve import KVCachePool, ServeEngine
from repro.tuner import TuningCache

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

#: one representative arch per CacheAdapter family
FAMILIES = ["smollm-135m", "deepseek-moe-16b", "mamba2-1.3b",
            "zamba2-7b", "whisper-medium"]


@pytest.fixture(scope="module")
def f32_cfg():
    return dataclasses.replace(get_config("smollm-135m").reduced(),
                               dtype="float32")


def _paged_case(seed, b=3, t=64, g=2, d=8, bs=16):
    """A random paged-decode workload: disjoint per-row leases (ragged
    lengths, permuted physical blocks, unmapped -1 tails) over a random
    physical cache."""
    rng = np.random.default_rng(seed)
    nb = t // bs
    clen = rng.integers(1, t + 1, size=b)
    perm = list(rng.permutation(b * nb))
    tables = np.full((b, nb), -1, np.int64)
    for i in range(b):
        for j in range(-(-int(clen[i]) // bs)):
            tables[i, j] = perm.pop()
    k = rng.standard_normal((b, t, g, d)).astype(np.float32)
    v = rng.standard_normal((b, t, g, d)).astype(np.float32)
    q = rng.standard_normal((b, g, 1, d)).astype(np.float32)
    return q, k, v, tables, clen


# --------------------------------------------------------------------------- #
# Kernel-level parity: fused == gather + dense sweep
# --------------------------------------------------------------------------- #


def test_fused_matches_gather_plus_dense_sweep():
    """Across tuned ``block_s`` values, the fused sweep (reference AND
    Pallas-interpret kernel) reproduces gather-then-dense-decode on
    ragged leases with unmapped table tails — the zero-materialization
    read is the same math."""
    import jax.numpy as jnp

    from repro.kernels.paged_decode_attention import (
        paged_decode_attention_pallas, paged_decode_attention_ref)
    from repro.kernels.paged_gather import paged_gather_ref
    from repro.models.attention import decode_attention_grouped

    bs = 16
    q, k, v, tables, clen = _paged_case(0, bs=bs)
    kj, vj = jnp.asarray(k), jnp.asarray(v)
    tj, cj = jnp.asarray(tables), jnp.asarray(clen)
    kl = paged_gather_ref(kj, tj, bs)
    vl = paged_gather_ref(vj, tj, bs)
    expected = np.asarray(decode_attention_grouped(jnp.asarray(q),
                                                   kl, vl, cj))
    for block_s in (16, 32, 48, 64, 128):
        got = np.asarray(paged_decode_attention_ref(
            jnp.asarray(q), kj, vj, tj, cj, page_block=bs, block_s=block_s))
        np.testing.assert_allclose(got, expected, rtol=1e-5, atol=1e-5,
                                   err_msg=f"ref block_s={block_s}")
        if block_s % bs == 0:
            got_p = np.asarray(paged_decode_attention_pallas(
                jnp.asarray(q), kj, vj, tj, cj, page_block=bs,
                block_s=block_s, interpret=True))
            np.testing.assert_allclose(got_p, expected, rtol=1e-5,
                                       atol=1e-5,
                                       err_msg=f"pallas block_s={block_s}")


def test_fused_ref_honours_sliding_window():
    """The blocked fused reference carries the traced sliding-window
    mask the Pallas path declines — same masking as the dense sweep."""
    import jax.numpy as jnp

    from repro.kernels.paged_decode_attention import \
        paged_decode_attention_ref
    from repro.kernels.paged_gather import paged_gather_ref
    from repro.models.attention import decode_attention_grouped

    bs = 16
    q, k, v, tables, clen = _paged_case(1, bs=bs)
    kj, vj = jnp.asarray(k), jnp.asarray(v)
    tj, cj = jnp.asarray(tables), jnp.asarray(clen)
    kl = paged_gather_ref(kj, tj, bs)
    vl = paged_gather_ref(vj, tj, bs)
    for window in (4, 9):
        expected = np.asarray(decode_attention_grouped(
            jnp.asarray(q), kl, vl, cj, window=window))
        got = np.asarray(paged_decode_attention_ref(
            jnp.asarray(q), kj, vj, tj, cj, page_block=bs, block_s=32,
            window=window))
        np.testing.assert_allclose(got, expected, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------- #
# Engine-level parity: all five families, recycling + growth
# --------------------------------------------------------------------------- #


def _sequential_reference(cfg, params, prompts, max_new):
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_decode_step, make_prefill_step
    from repro.models import build_model
    from repro.runtime import sharding as shd
    from repro.serve import get_adapter

    model = build_model(cfg)
    extras = get_adapter(cfg.family).prefill_extras(model, 1)
    mesh = make_local_mesh(1, 1)
    outs = []
    for p in prompts:
        max_len = len(p) + max_new + 1
        plan = shd.resolve_plan(cfg, mesh,
                                ShapeConfig("serve", max_len, 1, "decode"))
        prefill = jax.jit(make_prefill_step(model, plan, max_len))
        decode = jax.jit(make_decode_step(model, plan))
        logits, cache = prefill(
            params, {"tokens": jnp.asarray([p], jnp.int32), **extras})
        out = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(max_new - 1):
            logits, cache = decode(params, cache,
                                   jnp.asarray([[out[-1]]], jnp.int32))
            lg = logits[:, 0] if logits.ndim == 3 else logits
            out.append(int(jnp.argmax(lg[0])))
        outs.append(out)
    return outs


#: 5 ragged requests through 2 slots (mid-decode recycling), including
#: one long prompt that forces a pool-length bucket step (growth)
_PROMPTS = [[7, 3, 99], [11, 5, 2, 42, 17, 101, 9],
            list(range(2, 38)), [250, 1], [33, 44, 55, 66]]
_MAX_NEW = 3


@pytest.mark.parametrize("arch", FAMILIES)
def test_fused_engine_token_exact_all_families(arch):
    """The fused default AND the gather ablation are token-exact against
    the one-request-at-a-time scalar-pos path for every CacheAdapter
    family, under mid-decode slot recycling and pool growth.  (For the
    attention-free ssm family the fused plan is ``None`` — the pin is
    that the default flip stays harmless end to end.)"""
    import jax

    from repro.models import build_model

    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    params = build_model(cfg).init(jax.random.key(0))
    ref = _sequential_reference(cfg, params, _PROMPTS, _MAX_NEW)

    for fused in (True, False):
        # whole-prompt prefill: the pin is BITWISE token equality with a
        # one-request-at-a-time reference, so the chunked default's
        # float-reordering (argmax flips on random-init weights) is
        # opted out — chunked parity has its own suite
        eng = ServeEngine(cfg, slots=2, max_len=64, params=params,
                          fused_decode=fused, prefill_chunk=None,
                          tuning_cache=TuningCache(path=None))
        reqs = [eng.submit(p, max_new_tokens=_MAX_NEW) for p in _PROMPTS]
        report = eng.run()
        assert report.summary.n_completed == len(_PROMPTS)
        for req, p, expected in zip(reqs, _PROMPTS, ref):
            assert report.outputs[req.rid][len(p):] == expected, \
                f"{arch} fused={fused}: tokens diverged"
        assert report.pool_growths >= 1, "mix never grew the pool"
        if not cfg.is_attention_free:
            plan = eng.router.resolve(eng.router.bucket(eng.pool.kv_len))
            assert plan.paged_decode_block is not None
            assert plan.paged_decode_block % eng._block_size == 0


def test_fused_pallas_path_token_exact(f32_cfg):
    """Under force-interpret (the Pallas decode path on CPU) the fused
    scalar-prefetch kernel and the gather-then-Pallas-sweep ablation
    produce identical tokens on identical traffic."""
    import jax

    from repro.kernels import ops
    from repro.models import build_model

    params = build_model(f32_cfg).init(jax.random.key(0))
    outs = {}
    with ops.force("interpret"):
        for fused in (True, False):
            eng = ServeEngine(f32_cfg, slots=2, max_len=64, params=params,
                              fused_decode=fused,
                              tuning_cache=TuningCache(path=None))
            reqs = [eng.submit(p, max_new_tokens=_MAX_NEW)
                    for p in _PROMPTS[:3]]
            report = eng.run()
            assert report.summary.n_completed == len(reqs)
            outs[fused] = [report.outputs[r.rid] for r in reqs]
    assert outs[True] == outs[False], \
        "Pallas fused decode changed tokens vs the gather path"


# --------------------------------------------------------------------------- #
# Block-table invariants (properties; hypothesis drivers below)
# --------------------------------------------------------------------------- #


def _check_live_tables_disjoint(ops, slots):
    """Random admit/retire/grow: live block tables stay pairwise
    disjoint, mapped entries stay inside the physical grid, and the
    pool's own conservation checks hold — after EVERY op."""
    pool = KVCachePool(slots, 64, block_size=16, max_len=256)
    live, rid = [], 0
    for kind, arg in ops:
        if kind == "admit":
            n = 1 + arg % pool.kv_len
            if pool.fits(n):
                pool.admit(rid, n)
                live.append(rid)
                rid += 1
        elif kind == "retire" and live:
            pool.retire(live.pop(arg % len(live)))
        elif kind == "grow":
            pool.grow(min(pool.kv_len + 16 * (1 + arg % 4), pool.max_len))
        held: set[int] = set()
        for r in live:
            row = {p for p in pool.block_table(r) if p >= 0}
            assert row, "live lease with no mapped blocks"
            assert not (held & row), "two live tables share a block"
            assert max(row) < pool.allocator.num_blocks, \
                "table points past the physical grid"
            held |= row
        pool.check()


def _check_column_major_roundtrip(slots, nb, bs, pid, pos):
    """The column-major grid mapping round-trips: pid -> (row, offset)
    -> pid, and ``flat_position`` decomposes uniquely back into (row,
    block, in-block offset)."""
    from repro.kernels.paged_gather import flat_position

    t = nb * bs
    pid = pid % (slots * nb)
    pos = pos % t
    row, off = pid % slots, (pid // slots) * bs
    assert row + (off // bs) * slots == pid          # mapping round-trips
    flat = int(flat_position(np.int64(pid), np.int64(pos), slots, t, bs))
    assert flat == row * t + off + pos % bs
    # the flat index decomposes uniquely — no two (pid, pos%bs) collide
    assert (flat // t, (flat % t) // bs, flat % bs) \
        == (row, off // bs, pos % bs)
    # the quantized pool's scale cell is the SAME identity: a token's
    # flat cache index, divided by the block size, is its block's flat
    # scale index — codes and scales can never resolve different blocks
    assert (pid % slots) * nb + pid // slots == flat // bs


def _check_retired_scatter_drops(seed):
    """Scatter writes through the block table touch EXACTLY the mapped
    rows' leased positions: rows whose table entry is unmapped (-1 — a
    retired slot) or whose position overruns the table write NOTHING,
    and no other cache byte moves (no aliasing)."""
    import jax.numpy as jnp

    from repro.kernels.paged_gather import flat_position
    from repro.models.attention import _cache_write

    rng = np.random.default_rng(seed)
    b, t, g, d, bs = 3, 32, 2, 4, 8
    nb = t // bs
    cache = rng.standard_normal((b, t, g, d)).astype(np.float32)
    perm = list(rng.permutation(b * nb))
    tables = np.full((b, nb), -1, np.int64)
    for i in range(b):
        for j in range(int(rng.integers(0, nb + 1))):   # 0 => retired row
            tables[i, j] = perm.pop()
    pos = rng.integers(0, t, size=b)
    new = rng.standard_normal((b, g, d)).astype(np.float32)
    out = np.asarray(_cache_write(
        jnp.asarray(cache), jnp.asarray(new), jnp.asarray(pos),
        page_tables=jnp.asarray(tables), page_block=bs))

    expected = cache.reshape(b * t, g, d).copy()
    for i in range(b):
        pid = tables[i, pos[i] // bs]
        if pid >= 0:                      # mapped: exactly one row moves
            expected[int(flat_position(pid, pos[i], b, t, bs))] = new[i]
    np.testing.assert_array_equal(out.reshape(b * t, g, d), expected)


def _check_scales_never_alias_across_recycles(seed):
    """Random admit/retire traffic through an int8 pool: after every
    prompt write, the new lease's scale cells hold ONLY the new
    tenant's scales (prompt blocks) or zero (lease tail), and no other
    cell — live tenants' or free blocks' — moved at all.  A recycled
    block can therefore never dequantize through a previous tenant's
    scale."""
    import jax.numpy as jnp

    from repro.serve import get_adapter

    rng = np.random.default_rng(seed)
    adapter = get_adapter("dense")
    n_l, slots, bs, g, hd = 2, 2, 8, 2, 4
    kv_len = 32
    nb = kv_len // bs
    cache = {"k": jnp.zeros((n_l, slots, kv_len, g, hd), jnp.int8),
             "v": jnp.zeros((n_l, slots, kv_len, g, hd), jnp.int8),
             "k_scale": jnp.zeros((n_l, slots, nb, g), jnp.float32),
             "v_scale": jnp.zeros((n_l, slots, nb, g), jnp.float32),
             "pos": jnp.zeros((slots,), jnp.int32)}
    pool = KVCachePool(slots, kv_len, block_size=bs, max_len=kv_len)
    live, rid = [], 0
    for _ in range(12):
        if live and (rng.random() < 0.4 or pool.free_slots == 0):
            pool.retire(live.pop(rng.integers(len(live))))
            continue
        proj = int(rng.integers(1, kv_len + 1))
        if not pool.fits(proj):
            continue
        plen = int(rng.integers(1, proj + 1))
        lease = pool.admit(rid, proj)
        live.append(rid)
        rid += 1
        pid = np.asarray(lease.blocks)
        tok = np.arange(plen)
        p = pid[tok // bs]
        pm = jnp.asarray((p % slots) * kv_len + (p // slots) * bs
                         + tok % bs, jnp.int32)
        sm = ((pid % slots) * nb + pid // slots).astype(np.int32)
        vals = rng.standard_normal((n_l, 1, plen, g, hd)).astype(np.float32)
        row = {"k": jnp.asarray(vals), "v": jnp.asarray(vals),
               "pos": jnp.asarray(plen, jnp.int32)}
        before = np.asarray(cache["k_scale"]).reshape(n_l, slots * nb, g)
        cache = adapter.write_row(cache, lease.slot, row, plen, kv_len,
                                  page_map=pm, scale_map=sm,
                                  page_block=bs)
        after = np.asarray(cache["k_scale"]).reshape(n_l, slots * nb, g)
        npb = -(-plen // bs)
        pad = npb * bs - plen
        v = np.pad(vals[:, 0], ((0, 0), (0, pad), (0, 0), (0, 0)))
        want = np.abs(v.reshape(n_l, npb, bs, g, hd)).max((2, 4)) / 127.0
        np.testing.assert_allclose(after[:, sm[:npb]], want, rtol=1e-5,
                                   err_msg="prompt scales wrong")
        assert not after[:, sm[npb:]].any(), \
            "lease tail kept a previous tenant's scale"
        untouched = np.ones(slots * nb, bool)
        untouched[sm] = False
        np.testing.assert_array_equal(after[:, untouched],
                                      before[:, untouched],
                                      err_msg="scale write aliased "
                                              "outside the lease")


if HAVE_HYPOTHESIS:
    table_ops_st = st.lists(
        st.tuples(st.sampled_from(["admit", "retire", "grow"]),
                  st.integers(1, 100)),
        min_size=1, max_size=60)

    @settings(max_examples=100, deadline=None)
    @given(ops=table_ops_st, slots=st.integers(1, 8))
    def test_live_tables_stay_disjoint(ops, slots):
        _check_live_tables_disjoint(ops, slots)

    @settings(max_examples=200, deadline=None)
    @given(slots=st.integers(1, 16), nb=st.integers(1, 32),
           bs=st.sampled_from([1, 8, 16, 32]),
           pid=st.integers(0, 1 << 16), pos=st.integers(0, 1 << 16))
    def test_column_major_grid_roundtrips(slots, nb, bs, pid, pos):
        _check_column_major_roundtrip(slots, nb, bs, pid, pos)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1 << 30))
    def test_retired_scatter_writes_drop(seed):
        _check_retired_scatter_drops(seed)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1 << 30))
    def test_scales_never_alias_across_recycles(seed):
        _check_scales_never_alias_across_recycles(seed)


def test_table_invariants_seeded_sweep():
    """Hypothesis-free fallback: the same block-table properties over
    seeded random cases, so the invariants are always exercised."""
    rng = random.Random(11)
    for _ in range(25):
        ops = [(rng.choice(["admit", "retire", "grow"]),
                rng.randint(1, 100)) for _ in range(rng.randint(1, 60))]
        _check_live_tables_disjoint(ops, rng.randint(1, 8))
        _check_column_major_roundtrip(
            rng.randint(1, 16), rng.randint(1, 32),
            rng.choice([1, 8, 16, 32]),
            rng.randint(0, 1 << 16), rng.randint(0, 1 << 16))
    for seed in range(5):
        _check_retired_scatter_drops(seed)
    for seed in range(3):
        _check_scales_never_alias_across_recycles(seed)


# --------------------------------------------------------------------------- #
# Executed-plan pins: spy, HLO, byte-identical unpaged path
# --------------------------------------------------------------------------- #


def test_tuned_paged_block_reaches_executed_kernel(f32_cfg, monkeypatch):
    """The router-resolved fused ``block_s`` AND table geometry must
    reach the kernel call the engine actually runs — not just sit in the
    memoized plan."""
    import jax

    from repro.kernels import paged_decode_attention as pda_mod
    from repro.models import build_model

    seen = []
    real = pda_mod.paged_decode_attention

    def spy(q, kc, vc, tables, clen, **kw):
        seen.append((int(kw["block_s"]), int(kw["page_block"]),
                     int(tables.shape[-1])))
        return real(q, kc, vc, tables, clen, **kw)

    monkeypatch.setattr(pda_mod, "paged_decode_attention", spy)
    params = build_model(f32_cfg).init(jax.random.key(0))
    eng = ServeEngine(f32_cfg, slots=2, max_len=64, params=params,
                      tuning_cache=TuningCache(path=None))
    eng.submit([1, 2, 3], max_new_tokens=2)
    report = eng.run()
    assert report.summary.n_completed == 1
    plan = eng.router.resolve(eng.router.bucket(eng.pool.kv_len))
    geo = eng.router._geometry()
    assert seen, "decode ran without the fused paged sweep"
    assert set(seen) == {(plan.paged_decode_block, geo["page_block"],
                          geo["max_blocks_per_row"])}


def test_paged_block_changes_lowered_step_not_logits(f32_cfg):
    """Changing the tuned fused ``block_s`` changes the compiled step
    (the schedule the tuner decided) while the logits stay fixed — the
    acceptance criterion that the paged plan is observable in execution,
    not only in the cached decision."""
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_decode_step
    from repro.models import build_model
    from repro.runtime import sharding as shd
    from repro.serve import get_adapter

    model = build_model(f32_cfg)
    params = model.init(jax.random.key(0))
    plan = shd.resolve_plan(f32_cfg, make_local_mesh(1, 1),
                            ShapeConfig("serve", 64, 2, "decode"))
    step = jax.jit(make_decode_step(model, plan),
                   static_argnames=("decode_block", "page_block",
                                    "paged_decode_block"))
    cache = get_adapter(f32_cfg.family).init_pool(model, 2, 64,
                                                  expand_kv=plan.expand_kv)
    cache["pos"] = jnp.asarray([5, 9], jnp.int32)
    toks = jnp.asarray([[3], [4]], jnp.int32)
    tables = jnp.asarray([[0, 2, -1, -1], [1, 3, -1, -1]], jnp.int32)

    hlo = {bs: step.lower(params, dict(cache), toks, page_tables=tables,
                          page_block=16, paged_decode_block=bs).as_text()
           for bs in (16, 32)}
    assert hlo[16] != hlo[32], \
        "paged_decode_block did not change the lowered step"
    l16, _ = step(params, dict(cache), toks, page_tables=tables,
                  page_block=16, paged_decode_block=16)
    l32, _ = step(params, dict(cache), toks, page_tables=tables,
                  page_block=16, paged_decode_block=32)
    np.testing.assert_allclose(np.asarray(l16), np.asarray(l32),
                               rtol=1e-4, atol=1e-4)


def test_unpaged_step_lowers_byte_identical_to_pre_pr_path(f32_cfg):
    """Without tables the decode step must route through exactly the
    code that existed before the fused kernel was threadable: identical
    lowering to a step that never mentions ``paged_decode_block``."""
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_decode_step
    from repro.models import build_model
    from repro.runtime import sharding as shd
    from repro.serve import get_adapter

    model = build_model(f32_cfg)
    params = model.init(jax.random.key(0))
    plan = shd.resolve_plan(f32_cfg, make_local_mesh(1, 1),
                            ShapeConfig("serve", 64, 2, "decode"))
    step = jax.jit(make_decode_step(model, plan),
                   static_argnames=("decode_block", "page_block",
                                    "paged_decode_block"))
    cache = get_adapter(f32_cfg.family).init_pool(model, 2, 64,
                                                  expand_kv=plan.expand_kv)
    cache["pos"] = jnp.asarray([5, 9], jnp.int32)
    toks = jnp.asarray([[3], [4]], jnp.int32)

    # same jit name as `step`, pre-PR argument surface
    def decode_step(params, cache, tokens, decode_block=None):
        from repro.runtime.sharding import make_ctx
        return model.decode_step(params, cache, tokens,
                                 ctx=make_ctx(plan),
                                 decode_block=decode_block)

    plain = jax.jit(decode_step, static_argnames=("decode_block",))
    for db in (None, 256):
        new_hlo = step.lower(params, dict(cache), toks,
                             decode_block=db).as_text()
        old_hlo = plain.lower(params, dict(cache), toks,
                              decode_block=db).as_text()
        assert new_hlo == old_hlo, \
            f"unpaged lowering drifted from the pre-PR path (db={db})"
