"""Optimizer: AdamW math, scanned==flat update, clipping, schedules,
int8 gradient compression bounds."""

import pytest
pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.optim import (AdamWConfig, adamw_update, clip_by_global_norm,
                         compress_grads_int8, dequantize_int8, global_norm,
                         init_opt_state, lr_at, quantize_int8)


def test_adamw_reference_step():
    """one step against hand-computed Adam."""
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0, clip_norm=1e9, schedule="constant")
    p = {"w": jnp.array([[1.0, 2.0]])}
    g = {"w": jnp.array([[0.5, -0.5]])}
    state = init_opt_state(p)
    newp, newstate, m = adamw_update(p, g, state, cfg)
    # step1: m=0.1g v=0.05g^2; mhat=g, vhat=g^2 -> upd = sign(g)
    want = p["w"] - 0.1 * jnp.sign(g["w"]) / (1 + cfg.eps / jnp.abs(g["w"]))
    np.testing.assert_allclose(np.asarray(newp["w"]), np.asarray(want),
                               rtol=1e-4)


def test_scanned_equals_flat():
    """blocks subtree scanned over layers == plain per-leaf update."""
    cfg = AdamWConfig(clip_norm=1e9)
    key = jax.random.key(0)
    p = {"blocks": {"w": jax.random.normal(key, (4, 8, 8))},
         "embed": {"t": jax.random.normal(key, (16, 8))}}
    g = jax.tree.map(lambda x: x * 0.01, p)
    s = init_opt_state(p)
    p1, s1, _ = adamw_update(p, g, s, cfg)                       # scanned
    p2, s2, _ = adamw_update(p, g, s, cfg, scanned_keys=())      # flat
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    for a, b in zip(jax.tree.leaves(s1["m"]), jax.tree.leaves(s2["m"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_convergence_on_quadratic():
    cfg = AdamWConfig(peak_lr=0.05, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, schedule="constant")
    p = {"x": jnp.array([5.0, -3.0])}
    s = init_opt_state(p)
    for _ in range(200):
        g = jax.tree.map(lambda x: 2 * x, p)
        p, s, _ = adamw_update(p, g, s, cfg)
    assert float(jnp.abs(p["x"]).max()) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(90 + 160), rtol=1e-5)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-3)
    # under the limit: unchanged
    same, _ = clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_bf16_moments_supported():
    cfg = AdamWConfig(clip_norm=1e9)
    p = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    g = {"w": jnp.full((8, 8), 0.1, jnp.bfloat16)}
    s = init_opt_state(p, moment_dtype=jnp.bfloat16)
    newp, news, _ = adamw_update(p, g, s, cfg)
    assert news["m"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.isfinite(newp["w"].astype(jnp.float32)).all())


@pytest.mark.parametrize("sched,frac", [("cosine", 0.1), ("wsd", 0.1),
                                        ("constant", 1.0)])
def test_schedules(sched, frac):
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1, schedule=sched)
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(lr_at(cfg, jnp.int32(10))), 1.0,
                               rtol=0.2)
    np.testing.assert_allclose(float(lr_at(cfg, jnp.int32(100))), frac,
                               rtol=0.15)


class TestCompression:
    @given(st.integers(0, 2 ** 31 - 1), st.floats(1e-3, 1e3))
    @settings(max_examples=50, deadline=None)
    def test_int8_roundtrip_error_bound(self, seed, scale):
        x = jax.random.normal(jax.random.key(seed % 1000), (256,)) * scale
        q, s = quantize_int8(x)
        back = dequantize_int8(q, s)
        max_abs = float(jnp.abs(x).max())
        assert float(jnp.abs(back - x).max()) <= max_abs / 127.0 + 1e-9

    def test_stochastic_rounding_unbiased(self):
        x = jnp.full((20000,), 0.3)
        q, s = quantize_int8(x, key=jax.random.key(0))
        mean = float(dequantize_int8(q, s).mean())
        np.testing.assert_allclose(mean, 0.3, rtol=2e-2)

    def test_compress_grads_tree(self):
        g = {"a": jax.random.normal(jax.random.key(0), (64, 64)),
             "b": jax.random.normal(jax.random.key(1), (8,))}
        out = compress_grads_int8(g, jax.random.key(2))
        for k in g:
            rel = float(jnp.abs(out[k] - g[k]).max()
                        / jnp.abs(g[k]).max())
            assert rel < 0.02
