"""Data pipeline: determinism, shard partition, restart safety, learnable
structure — with hypothesis property tests on the partition invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.configs import get_config
from repro.data import DataConfig, data_config_for, iterator, make_batch

CFG = DataConfig(vocab_size=256, seq_len=32, global_batch=8)


def test_deterministic():
    a = make_batch(CFG, step=7, shard=0, n_shards=1)
    b = make_batch(CFG, step=7, shard=0, n_shards=1)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_steps_differ():
    a = make_batch(CFG, 0, 0, 1)
    b = make_batch(CFG, 1, 0, 1)
    assert not np.array_equal(a["tokens"], b["tokens"])


@given(n_shards=st.sampled_from([1, 2, 4, 8]), step=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_shards_partition_the_global_batch(n_shards, step):
    """union of shards == the single-shard global batch, in order."""
    whole = make_batch(CFG, step, 0, 1)["tokens"]
    parts = [make_batch(CFG, step, s, n_shards)["tokens"]
             for s in range(n_shards)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), whole)


def test_restart_safety():
    """iterating from step k == slicing a fresh stream at k."""
    it = iterator(CFG, start_step=5)
    direct = make_batch(CFG, 5, 0, 1)
    np.testing.assert_array_equal(next(it)["tokens"], direct["tokens"])


def test_elastic_repartition():
    """after a shard-count change the stream still covers the batch."""
    before = [make_batch(CFG, 3, s, 4)["tokens"] for s in range(4)]
    after = [make_batch(CFG, 3, s, 2)["tokens"] for s in range(2)]
    np.testing.assert_array_equal(np.concatenate(before, 0),
                                  np.concatenate(after, 0))


def test_markov_structure_is_learnable():
    """~90% of transitions follow the Markov rule (an LM can learn it)."""
    b = make_batch(CFG, 0, 0, 1)
    t = b["tokens"].astype(np.int64)
    pred = (CFG.markov_a * t[:, :-1] + CFG.markov_b) % CFG.vocab_size
    frac = (pred == t[:, 1:]).mean()
    assert 0.8 < frac < 0.99


def test_tokens_in_range():
    b = make_batch(CFG, 11, 0, 1)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < CFG.vocab_size
    assert b["tokens"].dtype == np.int32


def test_modality_stubs():
    cfg = data_config_for(get_config("paligemma-3b").reduced(), 32, 4)
    b = make_batch(cfg, 0, 0, 1)
    assert b["patches"].shape[1] == 8            # reduced prefix_tokens
    cfg2 = data_config_for(get_config("whisper-medium").reduced(), 32, 4)
    b2 = make_batch(cfg2, 0, 0, 1)
    assert b2["frames"].shape[1] == 24           # reduced encoder_tokens
