"""Observability subsystem invariants (``repro.obs``).

Three layers of guarantees:

  * the ``Tracer`` itself — span nesting/parentage under an injected
    clock, the bounded ring, thread-safe counters, the ambient
    null-tracer protocol;
  * the export round trip — versioned JSONL (schema-skew rejection,
    torn-line tolerance) and the Chrome/Perfetto form;
  * the serving integration — every decode tick / prefill admit span
    carries its bucket key and EXECUTED plan, the feedback loop lands
    replayable ``source="measured"`` records in a profiler TraceStore,
    the drift report ranks buckets, and (the critical one) attaching a
    tracer leaves the engine's lowered decode HLO byte-identical —
    tracing is host-side bookkeeping that never enters jitted code.
"""

import json
import math
import threading

import pytest

from repro.obs import (NULL_TRACER, OBS_SCHEMA_VERSION, NullTracer, Tracer,
                       aggregate, chrome_trace, drift_report, get_tracer,
                       load_trace, set_tracer, using_tracer, write_trace)


class FakeClock:
    """Deterministic injectable clock: advances by ``step`` per read."""

    def __init__(self, step=1.0):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


# --------------------------------------------------------------------------- #
# Tracer core
# --------------------------------------------------------------------------- #


class TestTracer:
    def test_span_records_duration_from_injected_clock(self):
        tr = Tracer(clock=FakeClock(step=1.0))
        with tr.span("work", bucket=64):
            pass
        (rec,) = tr.spans()
        assert rec.name == "work"
        assert rec.attrs == {"bucket": 64}
        assert rec.dur == 1.0          # exactly one clock step inside
        assert rec.parent is None
        assert rec.t1 == rec.t0 + rec.dur

    def test_nested_spans_record_parentage(self):
        tr = Tracer(clock=FakeClock())
        with tr.span("outer") as outer:
            with tr.span("inner"):
                pass
            tr.instant("point")
        inner, point, outer_rec = tr.spans()
        assert [r.name for r in tr.spans()] == ["inner", "point", "outer"]
        assert inner.parent == outer.sid
        assert point.parent == outer.sid
        assert point.dur == 0.0
        assert outer_rec.parent is None
        # sids are unique and the ring is close-ordered (inner first)
        assert len({r.sid for r in tr.spans()}) == 3

    def test_set_attaches_attrs_to_open_span(self):
        tr = Tracer(clock=FakeClock())
        with tr.span("resolve", kernel="vecadd") as sp:
            sp.set(source="cache", probes=0)
        (rec,) = tr.spans()
        assert rec.attrs == {"kernel": "vecadd", "source": "cache",
                             "probes": 0}

    def test_ring_is_bounded_oldest_evicted(self):
        tr = Tracer(clock=FakeClock(), capacity=4)
        for i in range(10):
            tr.instant("ev", i=i)
        assert len(tr) == 4
        assert [r.attrs["i"] for r in tr.spans()] == [6, 7, 8, 9]

    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError):
            Tracer(capacity=0)

    def test_counters_are_thread_safe(self):
        tr = Tracer()
        n_threads, n_inc = 8, 2000

        def work():
            for _ in range(n_inc):
                tr.count("ticks")

        ts = [threading.Thread(target=work) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert tr.counters() == {"ticks": n_threads * n_inc}

    def test_gauge_keeps_last_value(self):
        tr = Tracer()
        tr.gauge("live", 1)
        tr.gauge("live", 3)
        assert tr.gauges() == {"live": 3}

    def test_clear_keeps_meta(self):
        tr = Tracer(clock=FakeClock(), meta={"arch": "x"})
        tr.instant("a")
        tr.count("c")
        tr.clear()
        assert len(tr) == 0 and tr.counters() == {}
        assert tr.meta == {"arch": "x"}


class TestNullTracerProtocol:
    def test_ambient_default_is_null(self):
        assert get_tracer() is NULL_TRACER
        assert not get_tracer().enabled

    def test_using_tracer_installs_and_restores(self):
        tr = Tracer()
        assert get_tracer() is NULL_TRACER
        with using_tracer(tr):
            assert get_tracer() is tr
        assert get_tracer() is NULL_TRACER

    def test_using_tracer_restores_on_exception(self):
        tr = Tracer()
        with pytest.raises(RuntimeError):
            with using_tracer(tr):
                raise RuntimeError("boom")
        assert get_tracer() is NULL_TRACER

    def test_set_tracer_none_resets_to_null(self):
        set_tracer(Tracer())
        try:
            assert get_tracer() is not NULL_TRACER
        finally:
            set_tracer(None)
        assert get_tracer() is NULL_TRACER

    def test_null_tracer_is_inert(self):
        t = NullTracer()
        with t.span("anything", x=1) as sp:
            sp.set(y=2)
        t.instant("e")
        t.count("c", 5)
        t.gauge("g", 1)
        t.meta["k"] = "v"              # writes never stick
        assert t.spans() == [] and t.counters() == {} and t.meta == {}
        assert len(t) == 0


# --------------------------------------------------------------------------- #
# Export round trip
# --------------------------------------------------------------------------- #


def _sample_tracer():
    tr = Tracer(clock=FakeClock(), meta={"arch": "toy", "layers": 2})
    with tr.span("decode_tick", bucket=64, decode_block=128,
                 paged_decode_block=32, tiles=(32, 128)):
        pass
    tr.instant("pool_grow", kv_len=128)
    tr.count("decode_ticks", 3)
    tr.gauge("live_slots", 2)
    return tr


class TestExport:
    def test_jsonl_round_trip(self, tmp_path):
        tr = _sample_tracer()
        path = write_trace(tr, str(tmp_path / "t.jsonl"))
        back = load_trace(path)
        assert back.meta == {"arch": "toy", "layers": 2}
        assert back.counters() == {"decode_ticks": 3}
        assert back.gauges() == {"live_slots": 2}
        a, b = tr.spans(), back.spans()
        assert [r.name for r in b] == [r.name for r in a]
        assert [r.sid for r in b] == [r.sid for r in a]
        assert [r.parent for r in b] == [r.parent for r in a]
        assert b[0].dur == a[0].dur
        assert b[0].attrs["bucket"] == 64
        # JSON has no tuples: tuple attrs come back as lists
        assert b[0].attrs["tiles"] == [32, 128]

    def test_jsonl_header_first_line(self, tmp_path):
        path = write_trace(_sample_tracer(), str(tmp_path / "t.jsonl"))
        header = json.loads(open(path).readline())
        assert header["kind"] == "repro-obs-trace"
        assert header["version"] == OBS_SCHEMA_VERSION
        assert header["meta"]["arch"] == "toy"

    def test_version_skew_rejected(self, tmp_path):
        path = write_trace(_sample_tracer(), str(tmp_path / "t.jsonl"))
        lines = open(path).read().splitlines()
        header = json.loads(lines[0])
        header["version"] = OBS_SCHEMA_VERSION + 1
        lines[0] = json.dumps(header)
        (tmp_path / "skew.jsonl").write_text("\n".join(lines))
        with pytest.raises(ValueError, match="version"):
            load_trace(str(tmp_path / "skew.jsonl"))

    def test_wrong_kind_rejected(self, tmp_path):
        p = tmp_path / "other.jsonl"
        p.write_text('{"version": 1, "kind": "something-else"}\n')
        with pytest.raises(ValueError, match="not a"):
            load_trace(str(p))

    def test_torn_lines_skipped_not_fatal(self, tmp_path):
        path = write_trace(_sample_tracer(), str(tmp_path / "t.jsonl"))
        with open(path, "a") as f:
            f.write('{"type": "span", "name": "torn", "t0": ')  # torn write
        back = load_trace(path)
        assert [r.name for r in back.spans()] == ["decode_tick", "pool_grow"]

    def test_chrome_trace_shape(self):
        doc = chrome_trace(_sample_tracer())
        by_ph = {}
        for ev in doc["traceEvents"]:
            by_ph.setdefault(ev["ph"], []).append(ev)
        (span,) = by_ph["X"]
        assert span["name"] == "decode_tick"
        assert span["dur"] == pytest.approx(1e6)     # 1s clock step in us
        assert span["args"]["bucket"] == 64
        (inst,) = by_ph["i"]
        assert inst["name"] == "pool_grow"
        assert {ev["name"] for ev in by_ph["C"]} == \
            {"decode_ticks", "live_slots"}
        assert doc["otherData"] == {"arch": "toy", "layers": 2}

    def test_chrome_json_round_trip(self, tmp_path):
        tr = _sample_tracer()
        path = write_trace(tr, str(tmp_path / "t.json"))
        back = load_trace(path)
        assert back.meta == {"arch": "toy", "layers": 2}
        names = [r.name for r in back.spans()]
        assert "decode_tick" in names and "pool_grow" in names
        dt = next(r for r in back.spans() if r.name == "decode_tick")
        assert dt.attrs["decode_block"] == 128
        assert dt.dur == pytest.approx(1.0)

    def test_empty_file_rejected(self, tmp_path):
        p = tmp_path / "empty.jsonl"
        p.write_text("")
        with pytest.raises(ValueError, match="empty"):
            load_trace(str(p))


# --------------------------------------------------------------------------- #
# Serving integration: spans -> feedback -> drift, and the HLO pin
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def traced_run():
    """One traced reduced-model serving run shared by the integration
    tests (engine construction + XLA compiles dominate the cost)."""
    from repro.serve import ServeEngine
    from repro.tuner import TuningCache

    tracer = Tracer()
    eng = ServeEngine("smollm-135m", slots=2, max_len=32, reduced=True,
                      tracer=tracer, tuning_cache=TuningCache(path=None),
                      prefill_chunk=None, verbose=False)
    for i, (plen, out) in enumerate([(4, 3), (7, 2), (5, 4), (3, 2)]):
        eng.submit(list(range(1, plen + 1)), max_new_tokens=out,
                   arrival=0.01 * i)
    eng.run()
    return tracer, eng


class TestServingSpans:
    def test_every_decode_tick_carries_bucket_and_executed_plan(
            self, traced_run):
        tracer, eng = traced_run
        ticks = [s for s in tracer.spans() if s.name == "decode_tick"]
        assert ticks, "run produced no decode ticks"
        for s in ticks:
            assert s.attrs["bucket"] == eng.pool.kv_len
            assert s.attrs["decode_block"], s.attrs
            # fused paged decode is the default: block_s must ride along
            assert s.attrs["paged_decode_block"], s.attrs
            assert 0 < s.attrs["live"] <= s.attrs["slots"]

    def test_every_prefill_carries_bucket_and_tiles(self, traced_run):
        tracer, _ = traced_run
        pres = [s for s in tracer.spans() if s.name == "prefill"]
        assert len(pres) == 4          # one per admitted request
        for s in pres:
            assert s.attrs["bucket"] >= s.attrs["prompt_len"]
            bq, bkv = s.attrs["tiles"]
            assert bq >= 1 and bkv >= 1

    def test_resolution_spans_nest_and_attribute(self, traced_run):
        tracer, _ = traced_run
        names = {s.name for s in tracer.spans()}
        assert {"bucket_resolve", "resolve_plan", "slot_recycle"} <= names
        cold = [s for s in tracer.spans() if s.name == "bucket_resolve"
                and s.attrs.get("provenance") == "cold"]
        assert cold, "no cold bucket resolution recorded"
        # dispatch spans opened during the cold resolve nest under it
        nested = [s for s in tracer.spans() if s.name == "resolve_plan"
                  and s.parent in {c.sid for c in cold}]
        assert nested, "resolve_plan spans did not nest under the bucket"

    def test_counters_and_meta(self, traced_run):
        tracer, eng = traced_run
        c = tracer.counters()
        assert c["admits"] == 4
        assert c["decode_ticks"] >= 1
        assert c["tokens_decoded"] >= c["decode_ticks"]
        m = tracer.meta
        assert m["layers"] == eng.cfg.num_layers
        assert m["head_dim"] == eng.cfg.head_dim
        assert m["hw"] == eng.router.hw.name
        assert m["paged"] and m["fused_decode"]

    def test_aggregate_groups_by_bucket_and_kernel(self, traced_run):
        tracer, _ = traced_run
        rows = aggregate(tracer.spans())
        phases = {(r.phase, r.kernel) for r in rows}
        assert ("decode", "paged_decode") in phases
        assert ("prefill", "flash_attention") in phases
        for r in rows:
            assert r.n == len(r.samples)
            assert r.total_s == pytest.approx(sum(r.samples))
            assert r.median_s <= r.total_s


class TestFeedbackLoop:
    def test_feedback_lands_replayable_measured_records(self, traced_run,
                                                        tmp_path):
        from repro.obs import feedback_to_store
        from repro.obs.feedback import _kernel_desc
        from repro.profiler import TraceStore
        from repro.profiler.cost import hybrid_refine

        tracer, eng = traced_run
        store = TraceStore(str(tmp_path / "serving.jsonl"), autosave=False)
        n = feedback_to_store(tracer.spans(), tracer.meta, eng.router.hw,
                              store)
        assert n > 0
        store.save()
        for m in store.records():
            assert m.source == "serving"
            assert m.median_s > 0

        rows = [r for r in aggregate(tracer.spans()) if r.phase == "decode"]
        ob = max(rows, key=lambda r: r.n)
        replay = TraceStore(str(tmp_path / "serving.jsonl"))
        res = hybrid_refine(ob.kernel, _kernel_desc(ob, tracer.meta),
                            eng.router.hw, store=replay, mode="cached")
        # the engine executed the roofline winner, so the serving record
        # IS among the survivors: the replay must land on measurement
        assert res.source == "measured"
        assert res.value == ob.value

    def test_drift_report_ranks_buckets(self, traced_run):
        tracer, eng = traced_run
        rep = drift_report(tracer.spans(), tracer.meta, eng.router.hw)
        assert rep.rows, "no drift rows from a traced run"
        assert rep.median_ratio > 0
        mags = [abs(math.log(r.drift)) for r in rep.rows]
        assert mags == sorted(mags, reverse=True), "rows not ranked"
        for r in rep.rows:
            assert r.ratio == pytest.approx(r.measured_s / r.predicted_s)
        # fleet-median normalization: a 10x threshold keeps only rows
        # genuinely far off the fleet, and the formatted table parses
        assert all(abs(math.log(c.drift)) > math.log(10.0)
                   for c in rep.candidates(threshold=10.0))
        assert "drift" in rep.format()

    def test_drift_empty_without_meta(self, traced_run):
        tracer, eng = traced_run
        rep = drift_report(tracer.spans(), {}, eng.router.hw)
        assert rep.rows == ()


class TestTracingNeverEntersJit:
    def test_decode_hlo_byte_identical_with_and_without_tracer(self):
        """THE overhead guarantee: a traced engine lowers the exact same
        decode step as an untraced one — spans wrap host-side around
        the jitted call, so XLA never sees the difference."""
        import jax
        import jax.numpy as jnp

        from repro.serve import ServeEngine
        from repro.tuner import TuningCache

        def build(tracer):
            return ServeEngine("smollm-135m", slots=2, max_len=32,
                               reduced=True, tracer=tracer,
                               tuning_cache=TuningCache(path=None),
                               verbose=False)

        plain, traced = build(None), build(Tracer())
        assert not plain.obs.enabled and traced.obs.enabled
        tables = jnp.asarray(plain._tables)
        args = dict(decode_block=128, page_tables=tables,
                    page_block=plain._block_size, paged_decode_block=16)
        hlo_plain = plain._decode.lower(
            plain.params, dict(plain._cache),
            jnp.asarray(plain._tokens), **args).as_text()
        hlo_traced = traced._decode.lower(
            plain.params, dict(traced._cache),
            jnp.asarray(traced._tokens), **args).as_text()
        assert hlo_plain == hlo_traced, \
            "attaching a tracer changed the lowered decode step"


# --------------------------------------------------------------------------- #
# trace_view CLI
# --------------------------------------------------------------------------- #


class TestTraceViewCLI:
    @pytest.fixture()
    def trace_view(self):
        import importlib.util
        import os

        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "tools", "trace_view.py")
        spec = importlib.util.spec_from_file_location("trace_view", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod

    def test_renders_traced_serving_run(self, trace_view, traced_run,
                                        tmp_path, capsys):
        tracer, _ = traced_run
        path = write_trace(tracer, str(tmp_path / "serve.json"))
        rc = trace_view.main([path, "--require-buckets", "--require-drift"])
        out = capsys.readouterr().out
        assert rc == 0
        assert "decode,32,paged_decode" in out
        assert "drift vs roofline" in out

    def test_require_flags_fail_on_bare_trace(self, trace_view, tmp_path,
                                              capsys):
        bare = Tracer(clock=FakeClock())
        with bare.span("unrelated"):
            pass
        path = write_trace(bare, str(tmp_path / "bare.jsonl"))
        assert trace_view.main([path]) == 0
        assert trace_view.main([path, "--require-buckets"]) == 1
        capsys.readouterr()
