"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU with correct shapes and
no NaNs; decode paths agree with the teacher-forced forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import build_model

ALL_ARCHS = list_configs()


def reduced_f32(name):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


def batch_for(m, b=2, s=24, key=1):
    cfg = m.cfg
    d = {}
    text = s
    if cfg.family == "vlm":
        text = s - cfg.prefix_tokens
        d["patches"] = jnp.full((b, cfg.prefix_tokens, cfg.d_model), 0.01,
                                m.dtype)
    if cfg.family == "encdec":
        d["frames"] = jnp.full((b, cfg.encoder_tokens, cfg.d_model), 0.01,
                               m.dtype)
    d["tokens"] = jax.random.randint(jax.random.key(key), (b, text), 0,
                                     cfg.vocab_size)
    d["labels"] = jnp.roll(d["tokens"], -1, 1)
    d["mask"] = jnp.ones((b, text), jnp.float32)
    return d, text


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_grads(arch):
    """One forward + one grad step: output shapes, finite values."""
    cfg = reduced_f32(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch, text = batch_for(m)
    logits, aux = m.forward(params, batch)[:2]
    expect_s = text + (cfg.prefix_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, expect_s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), arch

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: m.loss(p, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    """init_cache + one decode step: shapes + finiteness."""
    cfg = reduced_f32(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    cache = m.init_cache(batch=2, max_len=16)
    tok = jnp.ones((2, 1), jnp.int32)
    logits, cache2 = m.decode_step(params, cache, tok)
    lg = logits[:, 0] if logits.ndim == 3 else logits
    assert lg.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ["smollm-135m", "gemma3-27b", "qwen3-8b",
                                  "mamba2-1.3b", "whisper-medium",
                                  "paligemma-3b", "nemotron-4-340b",
                                  "qwen3-moe-235b-a22b"])
def test_prefill_decode_matches_forward(arch):
    """prefill(t[:n-1]) + decode(t[n-1]) == forward(t)[-1].

    MoE archs use relaxed tolerance: capacity-based routing drops differ
    between the two paths by construction (verified exact when capacity
    covers all slots in test_moe.py)."""
    cfg = reduced_f32(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    b, s = 2, 20
    batch, text = batch_for(m, b, s)
    toks = batch["tokens"]
    full = m.forward(params, batch)[0]
    pbatch = dict(batch, tokens=toks[:, :-1])
    _, cache = m.prefill(params, pbatch, max_len=32)
    lg, _ = m.decode_step(params, cache, toks[:, -1:])
    got = lg[:, 0] if lg.ndim == 3 else lg
    atol = 0.2 if cfg.family == "moe" else 1e-3
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, -1]),
                               atol=atol, rtol=0.1 if cfg.family == "moe"
                               else 1e-3)


def test_hybrid_step_decode_matches_forward():
    """zamba2: decoding token-by-token from scratch equals forward."""
    cfg = reduced_f32("zamba2-7b")
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    full = m.forward(params, {"tokens": toks})[0]
    cache = m.init_cache(2, 12)
    outs = []
    for t in range(8):
        lg, cache = m.decode_step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0] if lg.ndim == 3 else lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_matches_analytic(arch):
    """spec-tree parameter count ~= the analytic n_params() formula."""
    cfg = get_config(arch)
    m = build_model(cfg)
    analytic = cfg.n_params()
    actual = m.param_count()
    assert abs(actual - analytic) / analytic < 0.05, \
        (arch, actual / 1e9, analytic / 1e9)


def test_gemma3_local_global_flags():
    from repro.models.transformer import layer_flags
    cfg = get_config("gemma3-27b")
    flags = np.asarray(layer_flags(cfg))
    # 5 local then 1 global, repeating
    assert not flags[:5].any() and flags[5]
    assert flags.sum() == len(flags) // 6 + (1 if len(flags) % 6 == 0 else 0)


def test_vlm_prefix_attention_is_bidirectional():
    """a prefix patch change must affect EARLIER prefix positions' output
    (prefix-LM), but a suffix token change must not affect the prefix."""
    cfg = reduced_f32("paligemma-3b")
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    batch, text = batch_for(m, b=1, s=16)
    lg1 = m.forward(params, batch)[0]
    # perturb LAST patch -> first-position logits must change
    p2 = batch["patches"].at[:, -1].add(1.0)
    lg2 = m.forward(params, dict(batch, patches=p2))[0]
    assert not np.allclose(np.asarray(lg1[:, 0]), np.asarray(lg2[:, 0]))
    # perturb last TEXT token -> prefix logits unchanged (causality)
    t2 = batch["tokens"].at[:, -1].set((batch["tokens"][:, -1] + 1)
                                       % cfg.vocab_size)
    lg3 = m.forward(params, dict(batch, tokens=t2))[0]
    np.testing.assert_allclose(np.asarray(lg1[:, :cfg.prefix_tokens]),
                               np.asarray(lg3[:, :cfg.prefix_tokens]),
                               atol=1e-5)
