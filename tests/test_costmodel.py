"""Cost model calibration + the two XLA-CPU artifacts it works around.

If either pinned artifact disappears in a future jax (loop-aware
cost_analysis / native-bf16 CPU buffers), these tests flag that the
dry-run should switch back to compiled numbers.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import costmodel as cm
from repro.core.roofline import collective_stats_from_hlo
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import (StepConfig, abstract_train_state,
                                make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime import sharding as shd

TINY = ModelConfig(name="tiny", family="dense", num_layers=1, d_model=256,
                   num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=1024,
                   head_dim=64, dtype="float32")


def _flops(compiled) -> float:
    """cost_analysis() returns [dict] on older jax (roofline.py normalizes
    the same way)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return float(cost["flops"])


def test_xla_artifact_scan_flops_counted_once():
    """PINNED ASSUMPTION: cost_analysis does not multiply while-loop trip
    counts (this is why the roofline uses the analytic model)."""
    def one(a, b):
        return a @ b

    def scanned(a, b):
        c, _ = jax.lax.scan(lambda c, _: (c @ b, None), a, None, length=10)
        return c

    sh = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    f1 = _flops(jax.jit(one).lower(sh, sh).compile())
    f2 = _flops(jax.jit(scanned).lower(sh, sh).compile())
    assert f2 == pytest.approx(f1), \
        "cost_analysis became loop-aware — revisit core.costmodel usage"


@pytest.fixture(scope="module")
def tiny_setup():
    model = build_model(TINY)
    mesh = make_local_mesh(1, 1)
    shape = ShapeConfig("t", 512, 4, "train")
    plan = shd.resolve_plan(TINY, mesh, shape)
    return model, mesh, shape, plan


def test_train_flops_calibration(tiny_setup):
    """analytic flops within 15% of cost_analysis on a LOOP-FREE config
    (1 layer, 1 microbatch, seq == attention chunk)."""
    model, mesh, shape, plan = tiny_setup
    ts = make_train_step(model, AdamWConfig(), plan,
                         StepConfig(remat="none", microbatches=1))
    state = abstract_train_state(model, plan)
    batch = model.input_specs(shape)
    measured = _flops(jax.jit(ts).lower(state, batch).compile())
    analytic = cm.cell_cost(TINY, shape, plan, microbatches=1,
                            remat="none").flops
    assert 0.85 < analytic / measured < 1.25, (analytic, measured)


def test_prefill_flops_calibration(tiny_setup):
    model, mesh, shape, plan = tiny_setup
    sp = ShapeConfig("p", 512, 4, "prefill")
    pf = make_prefill_step(model, plan, max_len=512)
    params = model.abstract_params()
    measured = _flops(jax.jit(pf).lower(
        params, {"tokens": jax.ShapeDtypeStruct((4, 512), jnp.int32)}
    ).compile())
    analytic = cm.cell_cost(TINY, sp, plan).flops
    assert 0.85 < analytic / measured < 1.25


def test_decode_flops_calibration(tiny_setup):
    model, mesh, shape, plan = tiny_setup
    sd = ShapeConfig("d", 512, 4, "decode")
    dec = make_decode_step(model, plan)
    params = model.abstract_params()
    cache = model.init_cache(4, 512, abstract=True)
    measured = _flops(jax.jit(dec).lower(
        params, cache, jax.ShapeDtypeStruct((4, 1), jnp.int32)
    ).compile())
    analytic = cm.cell_cost(TINY, sd, plan).flops
    assert 0.85 < analytic / measured < 1.25


def test_memory_model_scales_with_microbatching():
    shape = ShapeConfig("t", 4096, 256, "train")
    cfg = TINY
    mesh = make_local_mesh(1, 1)
    plan = shd.resolve_plan(cfg, mesh, shape)
    c1 = cm.cell_cost(cfg, shape, plan, microbatches=1)
    c8 = cm.cell_cost(cfg, shape, plan, microbatches=8)
    assert c8.mem_bytes["remat_stash"] < c1.mem_bytes["remat_stash"]
    assert c8.flops == pytest.approx(c1.flops, rel=0.01)


def test_collective_parser():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024]{1,0} %x), replica_groups=[1,16]<=[16], dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = bf16[2,64]{1,0} reduce-scatter(bf16[32,64]{1,0} %z), replica_groups=[1,16]<=[16], dimensions={0}
    """
    st = collective_stats_from_hlo(hlo, world=16)
    assert st.count_by_kind == {"all-gather": 1, "all-reduce": 1,
                                "reduce-scatter": 1}
    assert st.bytes_by_kind["all-gather"] == pytest.approx(
        15 / 16 * 16 * 1024 * 2)
    assert st.bytes_by_kind["all-reduce"] == pytest.approx(
        2 * 3 / 4 * 256 * 4)
    assert st.bytes_by_kind["reduce-scatter"] == pytest.approx(
        15 / 16 * 32 * 64 * 2)
