"""Serving subsystem invariants.

Property tests (hypothesis, matching tests/test_signature_props.py's
style) over the jax-free management layer — block aliasing, slot
recycling, FIFO no-starvation, bucket legality — plus a small end-to-end
check that the ragged decode pool is token-exact against the sequential
scalar-pos path.

Each property is a plain ``_check_*`` function: hypothesis drives it
when installed; a seeded random sweep covers the same invariants when it
is not (CI installs requirements-dev and runs both).
"""

import dataclasses
import random

import pytest

from repro.configs.base import ShapeConfig, get_config
from repro.core.hw import TPU_REGISTRY
from repro.serve import (BlockAllocator, BucketRouter, BucketSpec,
                         KVCachePool, Request, Scheduler)
from repro.tuner import TuningCache

HW = TPU_REGISTRY["cpu_sim"]

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------------- #
# Properties (plain functions; drivers below)
# --------------------------------------------------------------------------- #


def _check_allocator_never_aliases(ops, num_blocks, block_size):
    """Slot recycling never aliases two live requests' blocks, blocks
    are conserved, and ownership stays in sync — after EVERY op."""
    a = BlockAllocator(num_blocks, block_size)
    live = []
    rid = 0
    for kind, arg in ops:
        if kind == "alloc":
            if a.can_alloc(arg):
                a.alloc(rid, arg)
                live.append(rid)
                rid += 1
        elif live:
            a.release(live.pop(arg % len(live)))
        a.check()
    assert set(a.holders()) == set(live)


def _check_pool_recycling(ops, slots):
    """Recycled slots are never double-booked; growth keeps leases."""
    pool = KVCachePool(slots, 64, block_size=8, max_len=8192)
    live = []
    rid = 0
    for kind, arg in ops:
        if kind == "admit":
            n = 1 + arg % pool.kv_len
            if pool.fits(n):
                pool.admit(rid, n)
                live.append(rid)
                rid += 1
        elif kind == "retire" and live:
            pool.retire(live.pop(arg % len(live)))
        elif kind == "grow":
            pool.grow(pool.kv_len + 8 * (1 + arg % 4))
        pool.check()
    assert pool.live == len(live)
    assert pool.free_slots == slots - len(live)


def _check_no_starvation_fifo(mix, slots, finish_flags):
    """Every submitted request completes (no starvation) and admission
    is strictly FIFO, under abstract decode ticks + early finishes."""
    pool = KVCachePool(slots, 64, block_size=8)
    sched = Scheduler(pool)
    reqs = [Request(prompt=[1] * p, max_new_tokens=o, arrival=float(i))
            for i, (p, o) in enumerate(mix)]
    for r in reqs:
        assert sched.submit(r)    # all fit one row: projected <= 32 < 64
    admitted_order = []
    t, guard = 0.0, 0
    flags = iter(finish_flags)
    while not sched.idle:
        guard += 1
        assert guard < 10_000, "scheduler livelocked"
        sched.poll(t)
        for r in sched.admissible():
            admitted_order.append(r.rid)
        finish_now = next(flags, False) if sched.live else False
        for r in list(sched.live):
            r.generated.append(0)
            if r.done or (finish_now and r is sched.live[0]):
                r.generated.extend(
                    [0] * (r.max_new_tokens - len(r.generated)))
                sched.finish(r)
        t += 1.0
    assert len(sched.completed) == len(reqs)          # nobody starved
    assert admitted_order == [r.rid for r in reqs]    # strict FIFO


def _check_bucket_quantization(n, mode, quantum):
    spec = BucketSpec(min_len=32, max_len=4096, mode=mode, quantum=quantum)
    q = spec.quantize(n)
    assert q >= n                          # a bucket always covers
    assert q <= spec.max_len               # and never exceeds the cap
    assert q in spec.lattice()             # and is on the finite lattice
    assert spec.quantize(q) == q           # quantization is idempotent
    with pytest.raises(ValueError):
        spec.quantize(spec.max_len + 1)


def _check_bucket_resolution_legal(need, slots):
    """Any lattice point resolves through the tuner to a legal kernel
    mapping; re-resolving is warm and zero-probe."""
    cfg = get_config("smollm-135m").reduced()
    router = BucketRouter(cfg, BucketSpec(min_len=32, max_len=2048),
                          slots=slots, hw=HW, cache=TuningCache(path=None))
    b = router.bucket(need)
    assert b.covers(slots, need)
    plan = router.resolve(b)
    assert plan.decode_block % 128 == 0 and 128 <= plan.decode_block <= 8192
    bq, bk = plan.prefill_blocks
    assert bq >= 8 and bk >= 128
    probes_before = router.stats.probes
    assert router.resolve(b) is plan           # router-level warm hit
    assert router.stats.probes == probes_before
    assert router.stats.warm >= 1


# --------------------------------------------------------------------------- #
# Hypothesis drivers
# --------------------------------------------------------------------------- #

if HAVE_HYPOTHESIS:
    ops_st = st.lists(st.tuples(st.sampled_from(["alloc", "free"]),
                                st.integers(1, 200)),
                      min_size=1, max_size=60)
    pool_ops_st = st.lists(
        st.tuples(st.sampled_from(["admit", "retire", "grow"]),
                  st.integers(1, 100)),
        min_size=1, max_size=60)
    mix_st = st.lists(st.tuples(st.integers(1, 24), st.integers(1, 8)),
                      min_size=1, max_size=25)

    @settings(max_examples=100, deadline=None)
    @given(ops=ops_st, num_blocks=st.integers(4, 64),
           block_size=st.integers(1, 32))
    def test_allocator_never_aliases(ops, num_blocks, block_size):
        _check_allocator_never_aliases(ops, num_blocks, block_size)

    @settings(max_examples=100, deadline=None)
    @given(ops=pool_ops_st, slots=st.integers(1, 8))
    def test_pool_recycling_invariants(ops, slots):
        _check_pool_recycling(ops, slots)

    @settings(max_examples=100, deadline=None)
    @given(mix=mix_st, slots=st.integers(1, 4),
           finish_flags=st.lists(st.booleans(), max_size=300))
    def test_no_starvation_and_fifo(mix, slots, finish_flags):
        _check_no_starvation_fifo(mix, slots, finish_flags)

    @settings(max_examples=200, deadline=None)
    @given(n=st.integers(1, 4096),
           mode=st.sampled_from(["pow2", "linear", "fixed"]),
           quantum=st.integers(8, 128))
    def test_bucket_quantization_covers_and_bounds(n, mode, quantum):
        _check_bucket_quantization(n, mode, quantum)

    @settings(max_examples=50, deadline=None)
    @given(need=st.integers(1, 2048), slots=st.integers(1, 16))
    def test_bucket_resolution_yields_legal_plan(need, slots):
        _check_bucket_resolution_legal(need, slots)


def test_invariants_seeded_sweep():
    """Hypothesis-free fallback: the same properties over seeded random
    cases, so the invariants are always exercised."""
    rng = random.Random(7)
    for _ in range(25):
        ops = [(rng.choice(["alloc", "free"]), rng.randint(1, 200))
               for _ in range(rng.randint(1, 60))]
        _check_allocator_never_aliases(ops, rng.randint(4, 64),
                                       rng.randint(1, 32))
        pops = [(rng.choice(["admit", "retire", "grow"]),
                 rng.randint(1, 100)) for _ in range(rng.randint(1, 60))]
        _check_pool_recycling(pops, rng.randint(1, 8))
        mix = [(rng.randint(1, 24), rng.randint(1, 8))
               for _ in range(rng.randint(1, 25))]
        flags = [rng.random() < 0.5 for _ in range(300)]
        _check_no_starvation_fifo(mix, rng.randint(1, 4), flags)
        _check_bucket_quantization(rng.randint(1, 4096),
                                   rng.choice(["pow2", "linear", "fixed"]),
                                   rng.randint(8, 128))
    for need, slots in [(1, 1), (200, 4), (2048, 16), (1000, 3)]:
        _check_bucket_resolution_legal(need, slots)


# --------------------------------------------------------------------------- #
# Deterministic scheduler/bucket behaviours
# --------------------------------------------------------------------------- #


def test_longer_request_waits_for_pool_growth():
    """A long request queued behind a short head must NOT be seated in
    rows that would truncate its cache — it waits for its turn at the
    head, when the engine grows the pool to its bucket."""
    pool = KVCachePool(2, 32, block_size=8, max_len=128)
    sched = Scheduler(pool)
    short = Request(prompt=[1] * 4, max_new_tokens=4)     # projected 8
    long_ = Request(prompt=[1] * 40, max_new_tokens=20)   # projected 60
    assert sched.submit(short) and sched.submit(long_)
    sched.poll(0.0)
    assert sched.admissible() == [short]
    assert sched.peek_need_len() == 60    # engine grows for the new head
    pool.grow(64)
    assert sched.admissible() == [long_]
    pool.check()


def test_oversize_request_rejected_at_submit():
    pool = KVCachePool(2, 32, block_size=8, total_blocks=4)  # 32 tokens total
    sched = Scheduler(pool)
    assert not sched.submit(Request(prompt=[1] * 40, max_new_tokens=8))
    assert sched.rejected and sched.idle


def test_gang_mode_admits_only_into_empty_pool():
    pool = KVCachePool(2, 64, block_size=8)
    sched = Scheduler(pool, mode="gang")
    for _ in range(4):
        sched.submit(Request(prompt=[1, 2], max_new_tokens=2, arrival=0.0))
    sched.poll(0.0)
    first = sched.admissible()
    assert len(first) == 2
    assert sched.admissible() == []       # pool busy: no recycling
    for r in first:
        r.generated = [0, 0]
        sched.finish(r)
    assert len(sched.admissible()) == 2   # empty again: next gang


def test_warm_bucket_is_zero_probe_across_routers():
    """A second router sharing the TuningCache answers the same bucket
    from the cache: zero refine probes (the serve_bench criterion)."""
    cfg = get_config("smollm-135m").reduced()
    cache = TuningCache(path=None)
    spec = BucketSpec(min_len=32, max_len=512)
    r1 = BucketRouter(cfg, spec, slots=4, hw=HW, cache=cache)
    r1.resolve(r1.bucket(200))
    assert r1.stats.probes > 0                 # cold: refined
    r2 = BucketRouter(cfg, spec, slots=4, hw=HW, cache=cache)
    r2.resolve(r2.bucket(200))
    assert r2.stats.probes == 0                # warm: pure cache hits
    assert r2.stats.cache_hits == 2            # decode + prefill kernels


# --------------------------------------------------------------------------- #
# End-to-end: the ragged pool is token-exact vs the sequential path
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def f32_cfg():
    return dataclasses.replace(get_config("smollm-135m").reduced(),
                               dtype="float32")


def _sequential_reference(cfg, params, prompts, max_new):
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_decode_step, make_prefill_step
    from repro.models import build_model
    from repro.runtime import sharding as shd
    from repro.serve import get_adapter

    model = build_model(cfg)
    extras = get_adapter(cfg.family).prefill_extras(model, 1)
    mesh = make_local_mesh(1, 1)
    outs = []
    for p in prompts:
        max_len = len(p) + max_new + 1
        plan = shd.resolve_plan(cfg, mesh,
                                ShapeConfig("serve", max_len, 1, "decode"))
        prefill = jax.jit(make_prefill_step(model, plan, max_len))
        decode = jax.jit(make_decode_step(model, plan))
        logits, cache = prefill(
            params, {"tokens": jnp.asarray([p], jnp.int32), **extras})
        out = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(max_new - 1):
            logits, cache = decode(params, cache,
                                   jnp.asarray([[out[-1]]], jnp.int32))
            lg = logits[:, 0] if logits.ndim == 3 else logits
            out.append(int(jnp.argmax(lg[0])))
        outs.append(out)
    return outs


def test_engine_matches_sequential_decode(f32_cfg):
    """Slot recycling + per-row positions must not change anyone's
    tokens: a 2-slot pool over 4 ragged requests reproduces the
    one-request-at-a-time scalar-pos outputs exactly."""
    import jax

    from repro.models import build_model
    from repro.serve import ServeEngine

    prompts = [[7, 3, 99], [11, 5, 2, 42, 17, 101, 9], [250, 1],
               [33, 44, 55, 66]]
    max_new = 4
    params = build_model(f32_cfg).init(jax.random.key(0))
    ref = _sequential_reference(f32_cfg, params, prompts, max_new)

    eng = ServeEngine(f32_cfg, slots=2, max_len=64, params=params,
                      tuning_cache=TuningCache(path=None))
    reqs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    report = eng.run()
    assert report.summary.n_completed == len(prompts)
    for req, p, expected in zip(reqs, prompts, ref):
        assert report.outputs[req.rid][len(p):] == expected
    # 4 requests through 2 slots: recycling happened, shapes stayed put
    assert report.compiled_decode_shapes == 1
    assert report.router_stats["probes"] > 0          # cold buckets refined


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-7b",
                                  "whisper-medium"])
def test_engine_matches_sequential_decode_families(arch):
    """The CacheAdapter pool is token-exact for the recurrent, hybrid,
    and encoder-decoder families too — slot recycling, bucket-padded
    (or, for ssm, exact-length) prefill, and per-row positions never
    change anyone's tokens vs the one-request-at-a-time path."""
    import jax

    from repro.models import build_model
    from repro.serve import ServeEngine

    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    prompts = [[7, 3, 99], [11, 5, 2, 42, 17, 101, 9], [250, 1],
               [33, 44, 55, 66]]
    max_new = 4
    params = build_model(cfg).init(jax.random.key(0))
    ref = _sequential_reference(cfg, params, prompts, max_new)

    # whole-prompt prefill: the pin is BITWISE equality with the
    # one-request-at-a-time reference; the chunked default reorders
    # float accumulation in the hybrid recurrence (argmax flips on
    # random-init weights) — chunked parity has its own suite
    eng = ServeEngine(cfg, slots=2, max_len=64, params=params,
                      prefill_chunk=None,
                      tuning_cache=TuningCache(path=None))
    reqs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    report = eng.run()
    assert report.summary.n_completed == len(prompts)
    for req, p, expected in zip(reqs, prompts, ref):
        assert report.outputs[req.rid][len(p):] == expected
    if cfg.is_attention_free:
        # a length-free cache never recompiles, no matter the traffic
        assert report.compiled_decode_shapes == 1


# --------------------------------------------------------------------------- #
# The tuned decode_block is consumed by the EXECUTED decode step
# --------------------------------------------------------------------------- #


def test_tuned_decode_block_parameterizes_executed_step(f32_cfg, monkeypatch):
    """The bucket-resolved ``decode_block`` must reach the attention
    sweep the engine actually runs — not just sit in the memoized plan."""
    import jax

    from repro.models import attention as attn_mod
    from repro.models import build_model
    from repro.serve import ServeEngine

    seen = []
    real = attn_mod.blocked_decode_attention

    def spy(*args, **kw):
        seen.append(int(kw["block"]))
        return real(*args, **kw)

    monkeypatch.setattr(attn_mod, "blocked_decode_attention", spy)
    params = build_model(f32_cfg).init(jax.random.key(0))
    # paged=False: the paged default reads through the FUSED kernel and
    # never reaches blocked_decode_attention — its executed-plan pin
    # lives in tests/test_paged_decode.py
    eng = ServeEngine(f32_cfg, slots=2, max_len=64, params=params,
                      paged=False, tuning_cache=TuningCache(path=None))
    eng.submit([1, 2, 3], max_new_tokens=2)
    report = eng.run()
    assert report.summary.n_completed == 1
    plan = eng.router.resolve(eng.router.bucket(eng.pool.kv_len))
    assert seen, "decode ran without the tuned attention sweep"
    assert set(seen) == {plan.decode_block}


def test_decode_block_changes_executed_step_not_tokens(f32_cfg):
    """Changing the tuned block changes the compiled kernel invocation
    (the mapping/schedule) while the math — and thus the tokens — stays
    fixed: the acceptance criterion that tuning is observable in
    execution rather than only in the cached decision."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_decode_step
    from repro.models import build_model
    from repro.runtime import sharding as shd
    from repro.serve import get_adapter

    model = build_model(f32_cfg)
    params = model.init(jax.random.key(0))
    plan = shd.resolve_plan(f32_cfg, make_local_mesh(1, 1),
                            ShapeConfig("serve", 256, 2, "decode"))
    step = jax.jit(make_decode_step(model, plan),
                   static_argnames=("decode_block",))
    cache = get_adapter(f32_cfg.family).init_pool(model, 2, 256,
                                                  expand_kv=plan.expand_kv)
    cache["pos"] = jnp.asarray([5, 9], jnp.int32)
    toks = jnp.asarray([[3], [4]], jnp.int32)

    hlo = {b: step.lower(params, dict(cache), toks,
                         decode_block=b).as_text() for b in (128, 256)}
    assert hlo[128] != hlo[256], \
        "decode_block did not change the lowered step"
    l128, _ = step(params, dict(cache), toks, decode_block=128)
    l256, _ = step(params, dict(cache), toks, decode_block=256)
    np.testing.assert_allclose(np.asarray(l128), np.asarray(l256),
                               rtol=1e-4, atol=1e-4)


def test_decode_block_reaches_pallas_kernel(f32_cfg, monkeypatch):
    """Under a Pallas-capable mode the tuned block arrives at the actual
    kernel call (``block_s=``), closing ROADMAP's 'decision only' gap."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import decode_attention as dak
    from repro.kernels import ops
    from repro.models import build_model
    from repro.serve import get_adapter

    seen = []
    real = dak.decode_attention_pallas

    def spy(*args, **kw):
        seen.append(int(kw["block_s"]))
        return real(*args, **kw)

    monkeypatch.setattr(dak, "decode_attention_pallas", spy)
    model = build_model(f32_cfg)
    params = model.init(jax.random.key(0))
    cache = get_adapter(f32_cfg.family).init_pool(model, 1, 128)
    cache["pos"] = jnp.asarray([6], jnp.int32)
    with ops.force("interpret"):
        logits, _ = model.decode_step(params, cache,
                                      jnp.asarray([[3]], jnp.int32),
                                      decode_block=128)
    assert seen and set(seen) == {128}
    assert np.isfinite(np.asarray(logits)).all()
