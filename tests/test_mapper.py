"""Eq. 1 + block/mesh planner: unit + hypothesis property tests."""

import pytest
pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.hw import TPU_REGISTRY, VortexParams, ceil_div
from repro.core import workload as W
from repro.core.mapper import (MappingPolicy, Regime, classify_regime,
                               plan_attention_blocks, plan_matmul_blocks,
                               plan_microbatch, plan_moe_capacity,
                               plan_vector_blocks, resolve_lws)

HW = TPU_REGISTRY["tpu_v5e"]


class TestEq1:
    def test_paper_example(self):
        # paper Fig.1: gws=128, hp=8 -> lws=16
        assert resolve_lws(128, 8) == 16

    def test_hp_exceeds_gws_resolves_to_1(self):
        # paper §3: "when hp exceeds the gws ... Eq. 1 resolves to lws=1"
        assert resolve_lws(100, 1024) == 1

    def test_regimes(self):
        assert classify_regime(1, 128, 8) is Regime.OVERSUBSCRIBED
        assert classify_regime(16, 128, 8) is Regime.EXACT
        assert classify_regime(64, 128, 8) is Regime.UNDERSUBSCRIBED

    @given(gws=st.integers(1, 1 << 22), hp=st.integers(1, 1 << 16))
    @settings(max_examples=200, deadline=None)
    def test_lws_covers_gws_without_waste(self, gws, hp):
        lws = resolve_lws(gws, hp)
        # coverage: lws * hp lanes can absorb all of gws in one call
        assert lws * hp >= gws
        # minimality: one less iteration per lane would need another call
        assert (lws - 1) * hp < gws or lws == 1


class TestVectorBlocks:
    @given(n=st.integers(1, 1 << 22),
           pol=st.sampled_from(list(MappingPolicy)))
    @settings(max_examples=100, deadline=None)
    def test_plan_invariants(self, n, pol):
        plan = plan_vector_blocks(W.vecadd(n), HW, pol)
        assert plan.block_elems >= 1
        assert plan.grid * plan.block_elems == plan.padded_gws >= n
        assert plan.vmem_bytes <= HW.vmem_budget_bytes or \
            plan.block_elems == HW.lane_parallelism
        assert 0 < plan.utilization <= 1.0

    def test_auto_beats_naive_grid(self):
        plan_a = plan_vector_blocks(W.vecadd(1 << 20), HW, MappingPolicy.AUTO)
        plan_n = plan_vector_blocks(W.vecadd(1 << 20), HW, MappingPolicy.NAIVE)
        assert plan_a.sequential_rounds <= plan_n.sequential_rounds


class TestMatmulBlocks:
    @given(m=st.integers(8, 8192), n=st.integers(8, 8192),
           k=st.integers(8, 8192), pol=st.sampled_from(list(MappingPolicy)))
    @settings(max_examples=100, deadline=None)
    def test_tiles_cover_and_fit(self, m, n, k, pol):
        p = plan_matmul_blocks(m, n, k, HW, pol)
        assert p.grid[0] * p.bm >= m and p.grid[1] * p.bn >= n
        assert p.grid[2] * p.bk >= k
        if pol is MappingPolicy.AUTO:
            assert p.vmem_bytes <= HW.vmem_budget_bytes
            assert p.bm % 8 == 0 and p.bn % 8 == 0

    def test_mxu_alignment(self):
        p = plan_matmul_blocks(4096, 4096, 4096, HW)
        assert p.bm % 128 == 0 and p.bn % 128 == 0 and p.bk % 128 == 0


class TestAttentionBlocks:
    @given(sq=st.integers(1, 1 << 16), skv=st.integers(128, 1 << 16))
    @settings(max_examples=60, deadline=None)
    def test_vmem_clamp(self, sq, skv):
        p = plan_attention_blocks(sq, skv, 128, HW)
        assert p.block_q >= 8 and p.block_k >= 128
        assert p.vmem_bytes <= HW.vmem_budget_bytes or \
            (p.block_q <= 128 and p.block_k <= 128)


class TestMeshPlan:
    @given(gb=st.integers(1, 4096), dp=st.sampled_from([1, 2, 8, 16, 32]),
           act=st.floats(1e6, 1e10), budget=st.floats(1e9, 2e10))
    @settings(max_examples=100, deadline=None)
    def test_microbatch_divides(self, gb, dp, act, budget):
        p = plan_microbatch(gb, dp, act, budget)
        assert p.per_device_batch * dp >= gb
        assert p.per_device_batch % p.num_microbatches == 0
        assert p.microbatch_per_device * p.num_microbatches \
            == p.per_device_batch

    def test_memory_regime_forces_accumulation(self):
        # activations 10x the budget -> must microbatch (paper's
        # "multiple kernel calls" regime, used productively)
        p = plan_microbatch(256, 16, 1e9, 4e9)
        assert p.num_microbatches >= 4
        assert p.regime is Regime.OVERSUBSCRIBED


class TestMoECapacity:
    @given(t=st.integers(1, 1 << 20), e=st.sampled_from([8, 64, 128]),
           k=st.integers(1, 8))
    @settings(max_examples=60, deadline=None)
    def test_capacity_covers_ideal(self, t, e, k):
        cap = plan_moe_capacity(t, e, k, ep_size=1)
        assert cap * e >= t * k          # slots cover all routed tokens
        assert cap % 8 == 0
