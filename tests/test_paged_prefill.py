"""Executed prefill flash tiles + physical KV paging (PR 5 acceptance).

Two pin families, mirroring PR 4's decode pins:

  * prefill-tile consumption — the BucketRouter-resolved (block_q,
    block_k) reaches the attention sweep the engine actually RUNS (spy),
    changing the tiles changes the lowered prefill while the logits stay
    fixed, and ``prefill_tiles=None`` lowers byte-identically to the
    GSPMD path that existed before the tiles were threadable;
  * physical block tables — the paged gather is exactly the dense read
    (token-exact), recycling never aliases two live requests' tables,
    and the ragged pool stays token-exact against the sequential decode
    path for ALL FIVE families with ``paged=True``.
"""

import dataclasses

import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_config
from repro.serve import ServeEngine
from repro.tuner import TuningCache


@pytest.fixture(scope="module")
def f32_cfg():
    return dataclasses.replace(get_config("smollm-135m").reduced(),
                               dtype="float32")


def _sequential_reference(cfg, params, prompts, max_new):
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_decode_step, make_prefill_step
    from repro.models import build_model
    from repro.runtime import sharding as shd
    from repro.serve import get_adapter

    model = build_model(cfg)
    extras = get_adapter(cfg.family).prefill_extras(model, 1)
    mesh = make_local_mesh(1, 1)
    outs = []
    for p in prompts:
        max_len = len(p) + max_new + 1
        plan = shd.resolve_plan(cfg, mesh,
                                ShapeConfig("serve", max_len, 1, "decode"))
        prefill = jax.jit(make_prefill_step(model, plan, max_len))
        decode = jax.jit(make_decode_step(model, plan))
        logits, cache = prefill(
            params, {"tokens": jnp.asarray([p], jnp.int32), **extras})
        out = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(max_new - 1):
            logits, cache = decode(params, cache,
                                   jnp.asarray([[out[-1]]], jnp.int32))
            lg = logits[:, 0] if logits.ndim == 3 else logits
            out.append(int(jnp.argmax(lg[0])))
        outs.append(out)
    return outs


# --------------------------------------------------------------------------- #
# Prefill tiles are consumed by the EXECUTED prefill
# --------------------------------------------------------------------------- #


def test_prefill_tiles_reach_executed_flash(f32_cfg, monkeypatch):
    """The router-resolved prompt-bucket tiles must reach the attention
    sweep the engine's prefill actually runs — not just sit in the
    memoized plan (the PR 4 criterion, now for prefill)."""
    import jax

    from repro.models import attention as attn_mod
    from repro.models import build_model

    seen = []
    real = attn_mod.tiled_prefill_attention

    def spy(*args, **kw):
        seen.append((int(kw["block_q"]), int(kw["block_k"])))
        return real(*args, **kw)

    monkeypatch.setattr(attn_mod, "tiled_prefill_attention", spy)
    params = build_model(f32_cfg).init(jax.random.key(0))
    # whole-prompt prefill: the pin is the tiled WHOLE-PROMPT sweep; the
    # chunked default consumes the tuned tile as its chunk width instead
    # (masked decode-style writes — test_chunked_prefill covers that)
    eng = ServeEngine(f32_cfg, slots=2, max_len=64, params=params,
                      prefill_chunk=None,
                      tuning_cache=TuningCache(path=None))
    eng.submit([1, 2, 3], max_new_tokens=2)
    report = eng.run()
    assert report.summary.n_completed == 1
    pb = eng.router.quantize_prompt(3)
    assert seen, "prefill ran without the tuned tile sweep"
    assert set(seen) == {eng.router.prefill_tiles(pb)}


def test_prefill_tiles_change_lowered_step_not_logits(f32_cfg):
    """Changing the tiles changes the compiled prefill (the schedule the
    tuner decided) while the logits stay fixed — and ``None`` keeps the
    GSPMD path BYTE-IDENTICAL to a prefill that never saw the tiles
    argument at all."""
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import make_prefill_step
    from repro.models import build_model
    from repro.runtime import sharding as shd

    model = build_model(f32_cfg)
    params = model.init(jax.random.key(0))
    plan = shd.resolve_plan(f32_cfg, make_local_mesh(1, 1),
                            ShapeConfig("serve", 32, 1, "decode"))
    step = jax.jit(make_prefill_step(model, plan, None),
                   static_argnames=("prefill_tiles",))
    batch = {"tokens": jnp.asarray([[5, 7, 11, 13, 17, 19, 23, 29] * 4],
                                   jnp.int32)}
    last = jnp.asarray([31], jnp.int32)

    hlo = {t: step.lower(params, batch, last, prefill_tiles=t).as_text()
           for t in ((8, 128), (16, 256))}
    assert hlo[(8, 128)] != hlo[(16, 256)], \
        "prefill tiles did not change the lowered step"

    l_a, _ = step(params, batch, last, prefill_tiles=(8, 128))
    l_b, _ = step(params, batch, last, prefill_tiles=(16, 256))
    np.testing.assert_allclose(np.asarray(l_a), np.asarray(l_b),
                               rtol=1e-4, atol=1e-4)

    # None must route through exactly the code the GSPMD path always ran:
    # identical lowering to a step that does not thread tiles at all
    def prefill_step(params, batch, last_pos):   # same jit name as `step`
        from repro.runtime.sharding import make_ctx
        return model.prefill(params, batch, batch["tokens"].shape[1],
                             last_pos=last_pos, ctx=make_ctx(plan))

    none_hlo = step.lower(params, batch, last, prefill_tiles=None).as_text()
    plain_hlo = jax.jit(prefill_step).lower(params, batch, last).as_text()
    assert none_hlo == plain_hlo, \
        "prefill_tiles=None altered the GSPMD prefill lowering"
    l_none, _ = step(params, batch, last, prefill_tiles=None)
    np.testing.assert_allclose(np.asarray(l_none), np.asarray(l_a),
                               rtol=1e-4, atol=1e-4)


def test_prefill_tiles_reach_pallas_kernel(f32_cfg, monkeypatch):
    """Under a Pallas-capable mode the tuned tiles arrive at the actual
    flash kernel call (``plan.block_q/block_k``), closing ROADMAP's
    'prefill tiles are decisions only' gap."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import flash_attention as fa_mod
    from repro.kernels import ops
    from repro.models import build_model

    seen = []
    real = fa_mod.flash_attention_pallas

    def spy(*args, **kw):
        seen.append((int(kw["plan"].block_q), int(kw["plan"].block_k)))
        return real(*args, **kw)

    monkeypatch.setattr(fa_mod, "flash_attention_pallas", spy)
    model = build_model(f32_cfg)
    params = model.init(jax.random.key(0))
    batch = {"tokens": jnp.asarray([[5, 7, 11, 13, 17, 19, 23, 29]],
                                   jnp.int32)}
    ref, _ = model.prefill(params, batch, 8)
    with ops.force("interpret"):
        logits, _ = model.prefill(params, batch, 8, prefill_tiles=(8, 128))
    assert seen and set(seen) == {(8, 128)}
    np.testing.assert_allclose(np.asarray(ref), np.asarray(logits),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- #
# Physical block tables
# --------------------------------------------------------------------------- #


def test_paged_gather_matches_dense_read():
    """The gather-by-block-table read is EXACTLY the dense read: for any
    block permutation, gathering the physical store through the tables
    reproduces the logical rows bit-for-bit (it is a pure copy), in both
    the reference and the Pallas (interpret) kernel."""
    import jax.numpy as jnp

    from repro.kernels.paged_gather import (paged_gather_pallas,
                                            paged_gather_ref)

    rng = np.random.default_rng(0)
    b, t, g, d, bs = 3, 64, 2, 8, 16
    nb = t // bs
    logical = rng.standard_normal((b, t, g, d)).astype(np.float32)
    # scatter the logical blocks into a permuted physical grid
    pids = rng.permutation(b * nb).reshape(b, nb)
    physical = np.zeros_like(logical)
    for row in range(b):
        for j in range(nb):
            pid = pids[row, j]
            prow, poff = pid % b, (pid // b) * bs
            physical[prow, poff:poff + bs] = logical[row, j * bs:(j + 1) * bs]
    tables = jnp.asarray(pids, jnp.int32)
    cache = jnp.asarray(physical)
    np.testing.assert_array_equal(
        np.asarray(paged_gather_ref(cache, tables, bs)), logical)
    np.testing.assert_array_equal(
        np.asarray(paged_gather_pallas(cache, tables, bs, interpret=True)),
        logical)


def test_block_tables_never_alias_across_recycling(f32_cfg):
    """Slot recycling re-points block tables; at every completion (and
    at the end) the LIVE rows' physical blocks must be pairwise disjoint
    — the aliasing invariant, now load-bearing for real cache bytes."""
    import jax

    from repro.models import build_model

    params = build_model(f32_cfg).init(jax.random.key(0))
    eng = ServeEngine(f32_cfg, slots=2, max_len=64, params=params,
                      paged=True, tuning_cache=TuningCache(path=None))

    def check_disjoint(req, now):
        held: set[int] = set()
        for r in eng.scheduler.live:
            mine = {int(p) for p in eng._tables[r.slot] if p >= 0}
            assert mine, f"live request {r.rid} has an unmapped table"
            assert not (held & mine), "block aliased by two live tables"
            held |= mine
        eng.pool.check()

    reqs = [eng.submit([1 + i] * (3 + 2 * i), max_new_tokens=3)
            for i in range(5)]
    report = eng.run(on_complete=check_disjoint)
    assert report.summary.n_completed == len(reqs)
    # retired slots are unmapped: a stale tenant can never write again
    assert (eng._tables == -1).all()


@pytest.mark.parametrize("arch", ["smollm-135m", "deepseek-moe-16b",
                                  "mamba2-1.3b", "zamba2-7b",
                                  "whisper-medium"])
def test_paged_engine_matches_sequential_decode(arch):
    """With physical block tables enabled, the ragged pool stays
    token-exact against the one-request-at-a-time scalar-pos path for
    every CacheAdapter family — scatter writes, gather reads, and block
    recycling never change anyone's tokens.  (For the attention-free ssm
    family paging is pure block accounting; the pin is that enabling it
    is still harmless end to end.)"""
    import jax

    from repro.models import build_model

    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    prompts = [[7, 3, 99], [11, 5, 2, 42, 17, 101, 9], [250, 1]]
    max_new = 3
    params = build_model(cfg).init(jax.random.key(0))
    ref = _sequential_reference(cfg, params, prompts, max_new)

    # whole-prompt prefill: the pin is BITWISE token equality with a
    # one-request-at-a-time reference, so the chunked default's
    # float-reordering (argmax flips on random-init weights for the
    # hybrid family) is opted out — chunked parity has its own suite
    eng = ServeEngine(cfg, slots=2, max_len=64, params=params, paged=True,
                      prefill_chunk=None,
                      tuning_cache=TuningCache(path=None))
    reqs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    report = eng.run()
    assert report.summary.n_completed == len(prompts)
    for req, p, expected in zip(reqs, prompts, ref):
        assert report.outputs[req.rid][len(p):] == expected


def test_paged_pool_rejects_illegal_geometry(f32_cfg):
    """Paged mode guards its physical grid: non-block-multiple lattice
    lengths and block budgets beyond the grid are config errors, not
    silent corruption."""
    from repro.serve import BucketSpec

    with pytest.raises(ValueError, match="divisible"):
        ServeEngine(f32_cfg, slots=2, max_len=48, paged=True,
                    block_size=32,
                    spec=BucketSpec(min_len=48, max_len=48, mode="fixed"),
                    tuning_cache=TuningCache(path=None))
    # a mid-lattice length that is not a block multiple must fail at
    # CONSTRUCTION, not at the mid-run growth that would first hit it
    with pytest.raises(ValueError, match="divisible"):
        ServeEngine(f32_cfg, slots=2, max_len=96, paged=True,
                    block_size=16,
                    spec=BucketSpec(min_len=48, max_len=96, mode="linear",
                                    quantum=24),
                    tuning_cache=TuningCache(path=None))
    # exact mode has no finite lattice: paging cannot pre-validate it
    with pytest.raises(ValueError, match="finite"):
        ServeEngine(f32_cfg, slots=2, max_len=64, paged=True,
                    spec=BucketSpec(min_len=32, max_len=64, mode="exact"),
                    tuning_cache=TuningCache(path=None))
    with pytest.raises(ValueError, match="exceeds the physical"):
        ServeEngine(f32_cfg, slots=2, max_len=64, paged=True,
                    total_blocks=64, tuning_cache=TuningCache(path=None))
