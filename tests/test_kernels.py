"""Per-kernel allclose sweeps: every Pallas kernel (interpret mode) vs its
pure-jnp oracle, across shapes, dtypes and mapping policies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hw import TPU_REGISTRY
from repro.core.mapper import MappingPolicy
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gcn_agg import gcn_aggregate_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.nn_search import nn_search_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.saxpy import saxpy_pallas
from repro.kernels.stencil import gaussian_blur_pallas
from repro.kernels.vecadd import vecadd_pallas

HW = TPU_REGISTRY["cpu_sim"]
POLICIES = list(MappingPolicy)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    x = jax.random.normal(jax.random.key(key), shape, jnp.float32) * scale
    return x.astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)


def close(a, b, dtype=jnp.float32, **kw):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               **(tol(dtype) | kw))


@pytest.mark.parametrize("n", [128, 1000, 4096, 5001])
@pytest.mark.parametrize("policy", POLICIES)
def test_vecadd(n, policy):
    x, y = rand(0, (n,)), rand(1, (n,))
    close(vecadd_pallas(x, y, hw=HW, policy=policy, interpret=True),
          ref.vecadd(x, y))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vecadd_dtypes(dtype):
    x, y = rand(0, (2048,), dtype), rand(1, (2048,), dtype)
    close(vecadd_pallas(x, y, hw=HW, interpret=True), ref.vecadd(x, y),
          dtype)


@pytest.mark.parametrize("n", [256, 3000])
def test_saxpy(n):
    x, y, a = rand(0, (n,)), rand(1, (n,)), jnp.float32(1.7)
    close(saxpy_pallas(a, x, y, hw=HW, interpret=True), ref.saxpy(a, x, y))


@pytest.mark.parametrize("mnk", [(64, 64, 64), (200, 300, 250),
                                 (128, 256, 512), (7, 13, 9)])
@pytest.mark.parametrize("policy", POLICIES)
def test_matmul_shapes(mnk, policy):
    m, n, k = mnk
    a, b = rand(0, (m, k), scale=0.5), rand(1, (k, n), scale=0.5)
    close(matmul_pallas(a, b, hw=HW, policy=policy, interpret=True),
          ref.matmul(a, b))


def test_matmul_bf16():
    a = rand(0, (128, 128), jnp.bfloat16)
    b = rand(1, (128, 128), jnp.bfloat16)
    close(matmul_pallas(a, b, hw=HW, interpret=True), ref.matmul(a, b),
          jnp.bfloat16)


@pytest.mark.parametrize("shape", [(64, 128), (100, 96), (300, 256)])
@pytest.mark.parametrize("policy", POLICIES)
def test_gaussian_blur(shape, policy):
    img = rand(0, shape)
    close(gaussian_blur_pallas(img, hw=HW, policy=policy, interpret=True),
          ref.gaussian_blur(img), atol=1e-5)


@pytest.mark.parametrize("ksize", [3, 5, 7])
def test_gaussian_blur_ksize(ksize):
    img = rand(0, (64, 64))
    close(gaussian_blur_pallas(img, hw=HW, ksize=ksize, interpret=True),
          ref.gaussian_blur(img, ksize=ksize), atol=1e-5)


@pytest.mark.parametrize("nq,nr,d", [(64, 128, 8), (100, 300, 16),
                                     (17, 511, 4)])
def test_nn_search(nq, nr, d):
    q, r = rand(0, (nq, d)), rand(1, (nr, d))
    idx, dist = nn_search_pallas(q, r, hw=HW, interpret=True, block_r=128)
    ridx, rdist = ref.nn_search(q, r)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    close(dist, rdist, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n,f,density", [(128, 32, 0.05), (200, 64, 0.02),
                                         (64, 128, 0.5)])
def test_gcn_aggregate(n, f, density):
    adj = (jax.random.uniform(jax.random.key(0), (n, n)) < density
           ).astype(jnp.float32)
    adjn = adj / jnp.maximum(adj.sum(1, keepdims=True), 1)
    feats = rand(1, (n, f))
    close(gcn_aggregate_pallas(adjn, feats, hw=HW, interpret=True,
                               block_s=64),
          ref.gcn_aggregate(adjn, feats), atol=1e-5)


def test_gcn_matches_edge_list_oracle():
    """dense-tile SpMM == segment-sum over the edge list."""
    n, f = 96, 16
    adj = (jax.random.uniform(jax.random.key(3), (n, n)) < 0.1
           ).astype(jnp.float32)
    feats = rand(1, (n, f))
    src, dst = jnp.nonzero(adj.T)
    w = adj.T[src, dst]
    dense = ref.gcn_aggregate(adj, feats)
    edges = ref.gcn_aggregate_edges(src, dst, w, feats, n)
    close(dense, edges, atol=1e-5)


@pytest.mark.parametrize("rows,d", [(64, 256), (300, 512), (1000, 128)])
def test_rmsnorm(rows, d):
    x, g = rand(0, (rows, d)), rand(1, (d,))
    close(rmsnorm_pallas(x, g, hw=HW, interpret=True), ref.rmsnorm(x, g),
          rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("sq,skv,causal", [(128, 128, True),
                                           (128, 128, False),
                                           (100, 256, True),
                                           (64, 64, True)])
def test_flash_attention(sq, skv, causal):
    d = 64
    q = rand(0, (sq, d), scale=0.5)
    k = rand(1, (skv, d), scale=0.5)
    v = rand(2, (skv, d), scale=0.5)
    close(flash_attention_pallas(q, k, v, hw=HW, causal=causal,
                                 interpret=True),
          ref.attention(q, k, v, causal=causal), rtol=1e-4, atol=1e-4)


def test_flash_attention_bf16():
    q = rand(0, (128, 128), jnp.bfloat16, 0.5)
    k = rand(1, (128, 128), jnp.bfloat16, 0.5)
    v = rand(2, (128, 128), jnp.bfloat16, 0.5)
    close(flash_attention_pallas(q, k, v, hw=HW, interpret=True),
          ref.attention(q, k, v), jnp.bfloat16)


@pytest.mark.parametrize("s,clen", [(512, 512), (1024, 700), (256, 1)])
def test_decode_attention(s, clen):
    d = 64
    q = rand(0, (d,), scale=0.5)
    kc = rand(1, (s, d), scale=0.5)
    vc = rand(2, (s, d), scale=0.5)
    close(decode_attention_pallas(q, kc, vc, clen, hw=HW, interpret=True),
          ref.decode_attention(q, kc, vc, jnp.int32(clen)),
          rtol=1e-4, atol=1e-4)


def test_ssd_chunked_vs_sequential():
    """the chunked SSD (training path) == step recurrence (decode path)."""
    L, H, P, G, N = 128, 4, 16, 2, 8
    x = rand(0, (L, H, P), scale=0.5)
    a = -jnp.abs(rand(1, (L, H))) * 0.1
    b = rand(2, (L, G, N), scale=0.3)
    c = rand(3, (L, G, N), scale=0.3)
    for chunk in (16, 32, 128):
        close(ref.ssd_chunked(x, a, b, c, chunk=chunk),
              ref.ssd_sequential(x, a, b, c), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_ssd_pallas_kernel(chunk):
    """Pallas SSD grid-sequential kernel == O(L) recurrence oracle."""
    from repro.kernels.ssd import ssd_pallas
    L, H, P, G, N = 256, 4, 32, 2, 16
    x = rand(0, (L, H, P), scale=0.5)
    a = -jnp.abs(rand(1, (L, H))) * 0.1
    b = rand(2, (L, G, N), scale=0.3)
    c = rand(3, (L, G, N), scale=0.3)
    got = ssd_pallas(x, a, b, c, chunk=chunk, interpret=True)
    want = ref.ssd_sequential(x, a, b, c)
    close(got, want, rtol=1e-3, atol=1e-3)


def test_ssd_pallas_ragged_chunk():
    from repro.kernels.ssd import ssd_pallas
    L, H, P, G, N = 192, 2, 16, 1, 8
    x = rand(0, (L, H, P), scale=0.5)
    a = -jnp.abs(rand(1, (L, H))) * 0.1
    b = rand(2, (L, G, N), scale=0.3)
    c = rand(3, (L, G, N), scale=0.3)
    # 192 % 128 != 0 -> planner halves the chunk until it divides
    got = ssd_pallas(x, a, b, c, chunk=128, interpret=True)
    close(got, ref.ssd_sequential(x, a, b, c), rtol=1e-3, atol=1e-3)
