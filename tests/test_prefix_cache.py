"""Radix prefix sharing is INVISIBLE in the tokens (PR 10).

The whole contract of ``--prefix-cache`` is that aliasing physical KV
blocks and resuming prefill mid-prompt is an *execution* optimisation:

  * token exactness — on a 90%-shared-preamble mix driven through
    mid-decode slot recycling AND a pool-length growth step, every
    family's greedy streams are byte-identical with the radix on and
    off.  Dense (the shareable family) must actually HIT; for everyone
    else ``prefix_cache=True`` must be a clean no-op;
  * the MoE exclusion — expert-capacity routing couples a token's
    output to its routing-group chunk-mates, so a cached prefix block
    is NOT a pure function of prefix tokens; the adapter registry pins
    ``shareable_prefix=False`` and the engine must refuse to build a
    radix for it (the exactness run then holds trivially);
  * int8 interaction — shared blocks share their per-(block, head)
    scale rows; the radix-on int8 engine tracks its radix-on fp32 twin
    within the PR 9 logit-error bound and reproduces the radix-off
    token streams exactly on this mix;
  * HLO pin — ``prefix_cache`` is data, not program: the engine lowers
    byte-identical decode/prefill steps whether the flag is off,
    defaulted, or on, and turning the radix ON never adds compiled
    chunk shapes (resume offsets ride the traced ``cache["pos"]``).

Streams are compared POSITIONALLY (``req.generated`` per submitted
request) — request ids are a process-global counter, so two engines
never see the same rids for the same traffic.
"""

import dataclasses

import numpy as np
import pytest

from repro.configs.base import get_config
from repro.serve import ADAPTERS, ServeEngine
from repro.tuner import TuningCache

FAMILIES = ["smollm-135m", "deepseek-moe-16b", "mamba2-1.3b",
            "zamba2-7b", "whisper-medium", "paligemma-3b"]

_MAX_NEW = 3

#: one 24-token preamble (1 full 16-token block + an 8-token tail) in
#: front of ~90% of the mix, plus a long request that steps the pool
#: length bucket and two slots so retirement recycles mid-decode
_PRE = [5, 9, 2, 14, 7, 3, 11, 6, 4, 13, 8, 1, 10, 12, 15, 7,
        9, 3, 5, 2, 8, 11, 4, 6]


def _shared_mix():
    return [
        _PRE + [101, 102, 103],
        _PRE + [77] * 9,
        _PRE + list(range(120, 134)),          # 38 tokens: growth
        [250, 1],                              # the cold 10%
        _PRE + [33, 44],
        _PRE[:20] + [9, 9, 9],                 # partial-preamble branch
    ]


def _drive(cfg, params, prefix_cache, *, kv_dtype="fp32", spy_logits=None,
           slots=2, max_len=96):
    eng = ServeEngine(cfg, slots=slots, max_len=max_len, params=params,
                      tuning_cache=TuningCache(path=None),
                      kv_dtype=kv_dtype, prefix_cache=prefix_cache)
    if spy_logits is not None:
        real = eng._decode

        def spy(*a, **kw):
            lg, cache = real(*a, **kw)
            spy_logits.append(np.asarray(lg))
            return lg, cache

        eng._decode = spy
    reqs = [eng.submit(p, max_new_tokens=_MAX_NEW) for p in _shared_mix()]
    report = eng.run()
    assert report.summary.n_completed == len(reqs)
    return eng, report, [list(r.generated) for r in reqs]


@pytest.fixture(scope="module")
def family_setups():
    import jax

    from repro.models import build_model

    out = {}
    for arch in FAMILIES:
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  dtype="float32")
        out[arch] = (cfg, build_model(cfg).init(jax.random.key(0)))
    return out


# --------------------------------------------------------------------------- #
# Token exactness, all six registered families
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("arch", FAMILIES)
def test_token_streams_identical_radix_on_off(arch, family_setups):
    """Byte-identical greedy streams with the radix on vs off, through
    recycling and growth.  Dense must actually share; every non-dense
    family must get a clean no-op (no radix object at all)."""
    cfg, params = family_setups[arch]
    e_off, r_off, toks_off = _drive(cfg, params, False)
    e_on, r_on, toks_on = _drive(cfg, params, True)
    assert toks_on == toks_off, \
        f"{arch}: prefix cache changed the token streams"
    assert r_off.radix is None and e_off._radix is None
    if cfg.family == "dense":
        assert e_on._radix is not None
        rx = r_on.radix
        assert rx["hits"] >= 4, rx          # 4 later preamble sharers
        assert rx["hit_tokens"] >= 4 * 16   # each reuses >= 1 full block
        assert rx["hit_rate"] > 0.5
        # sharing ends the run with a consistent trie + pool
        e_on._radix.check()
        e_on.pool.check()
    else:
        assert e_on._radix is None, \
            f"{arch}: radix must not engage off the dense family"
        assert r_on.radix is None


def test_dense_growth_and_recycling_happened(family_setups):
    """The mix is only a test if it exercises the hard paths: the dense
    run must step the pool-length bucket AND recycle a slot mid-run
    (6 requests through 2 slots), with the radix on."""
    cfg, params = family_setups["smollm-135m"]
    eng, rep, _ = _drive(cfg, params, True)
    assert rep.pool_growths >= 1, "mix never grew the pool"
    assert rep.summary.n_completed == 6 > eng.slots
    assert rep.radix["evicted_blocks"] >= 0      # eviction path reachable
    # every lease is gone: all blocks either free or radix-retained
    alloc = eng.pool.allocator
    held = alloc.holders()
    assert set(held) <= {"radix"}
    assert alloc.free_blocks + len(held.get("radix", [])) == alloc.num_blocks


# --------------------------------------------------------------------------- #
# The MoE exclusion is pinned, not accidental
# --------------------------------------------------------------------------- #


def test_moe_is_not_shareable_by_contract(family_setups):
    """Capacity routing makes an MoE token's output depend on its
    routing-group chunk-mates (including pads and another request's
    private suffix), so a cached prefix block is not a pure function of
    the prefix tokens.  The adapter registry must say so, and the
    engine must refuse to build a radix for it."""
    assert not getattr(ADAPTERS["moe"], "shareable_prefix", False)
    assert getattr(ADAPTERS["dense"], "shareable_prefix", False)
    cfg, params = family_setups["deepseek-moe-16b"]
    eng = ServeEngine(cfg, slots=2, max_len=96, params=params,
                      tuning_cache=TuningCache(path=None),
                      prefix_cache=True)
    assert eng._radix is None


# --------------------------------------------------------------------------- #
# int8: shared blocks share scale rows, error stays in the PR 9 bound
# --------------------------------------------------------------------------- #


def test_int8_radix_tracks_fp32_radix_within_bound(family_setups):
    """With the radix ON, the int8 pool's per-tick decode logits stay
    within the PR 9 bound of the fp32 pool's (shared blocks share their
    per-(block, head) scale rows — refcount > 1 blocks are never
    re-quantized), and the argmax streams equal the radix-off runs."""
    cfg, params = family_setups["smollm-135m"]
    l32, l8 = [], []
    e32, r32, t32 = _drive(cfg, params, True, spy_logits=l32)
    e8, r8, t8 = _drive(cfg, params, True, kv_dtype="int8", spy_logits=l8)
    assert r32.radix["hits"] >= 4 and r8.radix["hits"] >= 4
    assert len(l32) == len(l8), "tick schedules diverged"
    err = max(float(np.max(np.abs(a - b))) for a, b in zip(l32, l8))
    scale = max(float(np.max(np.abs(a))) for a in l32)
    assert err <= 0.05 * scale, \
        f"int8+radix logit error {err:.4f} vs fp32 scale {scale:.2f}"
    _, _, t_off = _drive(cfg, params, False)
    assert t32 == t_off, "fp32 radix changed tokens"
    assert t8 == t_off, "int8 radix changed tokens on this mix"


# --------------------------------------------------------------------------- #
# HLO pin: prefix sharing is data (tables / traced pos), never program
# --------------------------------------------------------------------------- #


def test_decode_and_prefill_lower_identically(family_setups):
    """``prefix_cache=False`` (and the kwarg's default) lower the exact
    same decode and prefill steps as ``prefix_cache=True``: the radix
    moves block ids host-side; XLA never sees it."""
    import jax.numpy as jnp

    cfg, params = family_setups["smollm-135m"]

    def build(**kw):
        return ServeEngine(cfg, slots=2, max_len=96, params=params,
                           tuning_cache=TuningCache(path=None), **kw)

    default, off, on = (build(), build(prefix_cache=False),
                        build(prefix_cache=True))

    def decode_hlo(eng):
        tables = jnp.asarray(eng._tables)
        return eng._decode.lower(
            eng.params, dict(eng._cache), jnp.asarray(eng._tokens),
            decode_block=128, page_tables=tables,
            page_block=eng._block_size, paged_decode_block=16).as_text()

    assert decode_hlo(off) == decode_hlo(default), \
        "prefix_cache=False no longer lowers the pre-radix decode step"
    assert decode_hlo(on) == decode_hlo(off), \
        "enabling the radix changed the lowered decode step"

    def prefill_hlo(eng):
        toks = jnp.zeros((1, 32), jnp.int32)
        return eng._prefill.lower(
            eng.params, {"tokens": toks},
            last_pos=jnp.asarray([7], jnp.int32),
            prefill_tiles=None).as_text()

    assert prefill_hlo(off) == prefill_hlo(default)
    assert prefill_hlo(on) == prefill_hlo(off), \
        "enabling the radix changed the lowered prefill step"


def test_radix_never_adds_chunk_shapes(family_setups):
    """Resuming mid-prompt rides the traced ``cache['pos']`` — the
    radix-on run compiles NO chunk-prefill shape the radix-off run
    doesn't, and the decode shape census matches exactly."""
    cfg, params = family_setups["smollm-135m"]
    e_off, r_off, _ = _drive(cfg, params, False)
    e_on, r_on, _ = _drive(cfg, params, True)
    assert e_on.compiled_chunk_shapes <= e_off.compiled_chunk_shapes, (
        "radix-on compiled chunk shapes the radix-off engine never saw: "
        f"{e_on.compiled_chunk_shapes - e_off.compiled_chunk_shapes}")
    assert r_on.compiled_decode_shapes == r_off.compiled_decode_shapes
