"""Chunked-prefill invariants (``ServeEngine(prefill_chunk=...)``).

The engine can prefill prompts in fixed-width chunks interleaved with
decode ticks instead of one whole-prompt pass.  The guarantees:

  * dense/MoE chunking is token-EXACT against the whole-prompt path —
    the chunk step writes the same cache and produces the same
    final-position logits (causal masking hides the padded tail, so no
    validity mask is needed);
  * the chunk compile set is bounded by the (chunk, cache_len, tiles)
    lattice, NOT by prompt lengths — in particular the ssm family's
    length-free row cache compiles exactly ONE chunk step no matter how
    many distinct exact prompt lengths arrive (the compile-set leak the
    whole-prompt exact-length path has);
  * outputs are chunk-size invariant: any chunk width produces the same
    tokens;
  * interleaving holds: with a long prompt in flight, decode ticks of
    already-seated requests keep landing between its chunks.
"""

import dataclasses

import pytest

from repro.configs.base import get_config
from repro.tuner import TuningCache

PROMPTS = [[7, 3, 99], [11, 5, 2, 42, 17, 101, 9], [250, 1],
           [33, 44, 55, 66]]
MAX_NEW = 4


@pytest.fixture(scope="module")
def dense_setup():
    import jax

    from repro.models import build_model

    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              dtype="float32")
    return cfg, build_model(cfg).init(jax.random.key(0))


@pytest.fixture(scope="module")
def ssm_setup():
    import jax

    from repro.models import build_model

    cfg = dataclasses.replace(get_config("mamba2-1.3b").reduced(),
                              dtype="float32")
    return cfg, build_model(cfg).init(jax.random.key(0))


def _run(cfg, params, prompts=PROMPTS, **kw):
    from repro.serve import ServeEngine

    eng = ServeEngine(cfg, slots=2, max_len=64, params=params,
                      tuning_cache=TuningCache(path=None), **kw)
    reqs = [eng.submit(p, max_new_tokens=MAX_NEW) for p in prompts]
    rep = eng.run()
    return [rep.outputs[r.rid] for r in reqs], rep


class TestDenseExactness:
    def test_chunked_matches_whole_prefill(self, dense_setup):
        cfg, params = dense_setup
        whole, _ = _run(cfg, params, prefill_chunk=None)
        for chunk in (2, 3):
            chunked, rep = _run(cfg, params, prefill_chunk=chunk)
            assert chunked == whole, f"chunk={chunk} changed tokens"
            assert rep.summary.n_completed == len(PROMPTS)

    def test_auto_chunk_uses_tuned_tile(self, dense_setup):
        cfg, params = dense_setup
        whole, _ = _run(cfg, params, prefill_chunk=None)
        chunked, rep = _run(cfg, params, prefill_chunk="auto")
        assert chunked == whole
        # auto = the prompt bucket's tuned block_q: every chunk shape in
        # the set must carry the tiles it was derived from
        assert rep.compiled_chunk_shapes >= 1

    def test_chunk_compile_set_is_lattice_bounded(self, dense_setup):
        """4 ragged prompts through one chunk width on one prompt
        bucket: exactly one compiled chunk shape."""
        cfg, params = dense_setup
        _, rep = _run(cfg, params, prefill_chunk=2)
        assert rep.compiled_chunk_shapes == 1
        assert rep.compiled_decode_shapes == 1

    def test_exact_mode_clamps_auto_chunk_to_prompt(self, dense_setup):
        """mode="exact" prompt buckets are the RAW prompt length while
        the auto chunk width (the tuned block_q) is padded up to a tile
        multiple — the chunk must clamp to the row or the chunked cache
        write overruns an exact-length cache."""
        from repro.serve import BucketSpec

        cfg, params = dense_setup
        prompts = [list(range(1, 53))]       # 52 tokens: no tile multiple
        spec = BucketSpec(min_len=32, max_len=64, mode="exact")
        whole, _ = _run(cfg, params, prompts=prompts, prefill_chunk=None,
                        spec=spec, paged=False)
        chunked, rep = _run(cfg, params, prompts=prompts, spec=spec,
                            paged=False)          # default chunking on
        assert chunked == whole
        assert rep.summary.n_completed == 1

    def test_invalid_chunk_config_rejected(self, dense_setup):
        from repro.serve import ServeEngine

        cfg, params = dense_setup
        with pytest.raises(ValueError):
            ServeEngine(cfg, slots=2, max_len=64, params=params,
                        tuning_cache=TuningCache(path=None),
                        prefill_chunk="huge")


class TestSsmCompileBound:
    def test_one_compile_across_distinct_exact_lengths(self, ssm_setup):
        """THE compile-set pin: the ssm whole-prompt path compiles one
        prefill per exact prompt length; the chunked path compiles ONE
        chunk step total — its row cache is length-free, so the compile
        key is the chunk width alone."""
        cfg, params = ssm_setup
        assert cfg.is_attention_free
        prompts = [[7, 3, 99], [11, 5, 2, 42, 17], [250, 1],
                   [33, 44, 55, 66, 77, 88], [9] * 9]   # 5 distinct lengths
        outs, rep = _run(cfg, params, prompts=prompts, prefill_chunk=4)
        assert rep.summary.n_completed == len(prompts)
        assert rep.compiled_chunk_shapes == 1
        assert rep.compiled_decode_shapes == 1      # length-free decode too
        for p, o in zip(prompts, outs):
            assert len(o) == len(p) + MAX_NEW

    def test_outputs_chunk_size_invariant(self, ssm_setup):
        """The masked scan-of-decode chunk step runs the exact per-token
        recurrence, so every chunk width produces identical tokens."""
        cfg, params = ssm_setup
        a, _ = _run(cfg, params, prefill_chunk=2)
        b, _ = _run(cfg, params, prefill_chunk=5)
        assert a == b


class TestInterleaving:
    def test_decode_proceeds_between_chunks_of_long_prompt(self,
                                                          dense_setup):
        """A long prompt admitted mid-run must NOT stall the decoding
        pool: decode ticks land between its prefill chunks, and its own
        tokens still come out exact."""
        from repro.serve import ServeEngine

        cfg, params = dense_setup
        long_prompt = list(range(1, 33))             # 16 chunks at width 2
        short = [5, 6, 7]

        whole, _ = _run(cfg, params, prompts=[short, long_prompt],
                        prefill_chunk=None)

        eng = ServeEngine(cfg, slots=2, max_len=64, params=params,
                          tuning_cache=TuningCache(path=None),
                          prefill_chunk=2)
        r1 = eng.submit(short, max_new_tokens=MAX_NEW)
        r2 = eng.submit(long_prompt, max_new_tokens=MAX_NEW)

        interleaved = {"chunks_seen": 0, "decodes_during": 0}
        orig_chunk, orig_decode = eng._prefill_tick, eng._decode_tick

        def chunk_tick():
            stepped = orig_chunk()
            if stepped and eng._prefilling.get(r2.rid):
                interleaved["chunks_seen"] += 1
            return stepped

        def decode_tick():
            if r2.rid in eng._prefilling:
                interleaved["decodes_during"] += 1
            orig_decode()

        eng._prefill_tick, eng._decode_tick = chunk_tick, decode_tick
        rep = eng.run()
        assert rep.outputs[r1.rid] == whole[0]
        assert rep.outputs[r2.rid] == whole[1]
        assert interleaved["chunks_seen"] >= 8
        # the short request decoded (all its post-first tokens) while the
        # long prompt was still mid-prefill
        assert interleaved["decodes_during"] >= MAX_NEW - 1

    def test_prefilling_rows_decode_no_tokens(self, dense_setup):
        """A still-prefilling request accrues no generated tokens from
        the interleaved decode ticks it rides along with."""
        from repro.serve import ServeEngine

        cfg, params = dense_setup
        eng = ServeEngine(cfg, slots=2, max_len=64, params=params,
                          tuning_cache=TuningCache(path=None),
                          prefill_chunk=2)
        r1 = eng.submit([5, 6, 7], max_new_tokens=MAX_NEW)
        r2 = eng.submit(list(range(1, 25)), max_new_tokens=MAX_NEW)
        rep = eng.run()
        assert len(rep.outputs[r2.rid]) == 24 + MAX_NEW
