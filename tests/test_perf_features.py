"""§Perf levers: banded attention, int8 KV cache, fp8 a2a, moe remat,
serve-mesh chooser — correctness of each beyond-paper optimization."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.models import transformer as tf
from repro.models.attention import banded_attention, chunked_attention
from repro.models.layers import ShardCtx


class TestBandedAttention:
    @pytest.mark.parametrize("window,band", [(32, 32), (32, 64), (64, 64)])
    def test_matches_masked_full_sweep(self, window, band):
        B, S, G, R, D = 2, 256, 2, 2, 16
        q = jax.random.normal(jax.random.key(0), (B, S, G, R, D)) * 0.5
        k = jax.random.normal(jax.random.key(1), (B, S, G, D)) * 0.5
        v = jax.random.normal(jax.random.key(2), (B, S, G, D)) * 0.5
        ref = chunked_attention(q, k, v, causal=True, window=window,
                                chunk=64)
        got = banded_attention(q, k, v, window=window, band=band)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5)

    def test_gradients_match(self):
        B, S, G, R, D = 1, 128, 1, 2, 8
        q = jax.random.normal(jax.random.key(0), (B, S, G, R, D)) * 0.5
        k = jax.random.normal(jax.random.key(1), (B, S, G, D)) * 0.5
        v = jax.random.normal(jax.random.key(2), (B, S, G, D)) * 0.5
        g1 = jax.grad(lambda q, k, v: (banded_attention(
            q, k, v, window=32, band=32) ** 2).sum(), (0, 1, 2))(q, k, v)
        g2 = jax.grad(lambda q, k, v: (chunked_attention(
            q, k, v, causal=True, window=32, chunk=32) ** 2).sum(),
            (0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-3)


class TestBandedGemmaForward:
    def test_grouped_forward_exact(self):
        cfg = ModelConfig(name="t", family="dense", num_layers=9,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=256, head_dim=16, window=32,
                          local_global_ratio=3, dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 128), 0, 256)
        ref, _, (rk, rv) = tf.forward(params, toks, cfg, return_cache=True)
        ctx = ShardCtx(flags={"banded_local": True})
        got, _, (gk, gv) = tf.forward(params, toks, cfg, ctx=ctx,
                                      return_cache=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-3)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(rk),
                                   atol=1e-5)


class TestInt8KVCache:
    def test_decode_close_to_bf16(self):
        cfg = dataclasses.replace(get_config("qwen3-8b").reduced(),
                                  dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 8), 0,
                                  cfg.vocab_size)
        c_f = m.init_cache(2, 12)
        c_q = m.init_cache(2, 12, cache_dtype="int8")
        assert c_q["k"].dtype == jnp.int8
        for t in range(8):
            lf, c_f = m.decode_step(params, c_f, toks[:, t:t + 1])
            lq, c_q = m.decode_step(params, c_q, toks[:, t:t + 1])
        rel = float(jnp.abs(lf - lq).max() / jnp.abs(lf).max())
        # bound is jaxlib-sensitive (matmul accumulation order shifts the
        # quantization-noise peak): 0.059 on 0.4.x CPU, under 0.05 on TPU
        assert rel < 0.08, rel


class TestFP8A2A:
    def test_moe_forward_close(self):
        cfg = dataclasses.replace(get_config("deepseek-moe-16b").reduced(),
                                  dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                  cfg.vocab_size)
        ref, _ = tf.forward(params, toks, cfg)
        ctx = ShardCtx(flags={"moe_fp8_a2a": True})
        got, _ = tf.forward(params, toks, cfg, ctx=ctx)
        rel = float(jnp.abs(ref - got).max() / jnp.abs(ref).max())
        assert rel < 0.15, rel          # fp8 e4m3, scale folded (doc'd)

    def test_moe_remat_policy_grads(self):
        cfg = dataclasses.replace(get_config("deepseek-moe-16b").reduced(),
                                  dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                  cfg.vocab_size)
        g = jax.grad(lambda p: (tf.forward(p, toks, cfg, remat="moe")[0]
                                .astype(jnp.float32) ** 2).mean())(params)
        for leaf in jax.tree.leaves(g):
            assert bool(jnp.isfinite(leaf).all())


class TestServeMeshChooser:
    def test_nemotron_needs_tp64(self):
        from repro.runtime.sharding import choose_serve_mesh
        dp, tp = choose_serve_mesh(get_config("nemotron-4-340b"))
        assert tp == 64 and dp * tp == 256
        # weights now fit model-only
        n = get_config("nemotron-4-340b").n_params() * 2
        assert n / tp <= 12 * 1024**3

    def test_small_model_keeps_default(self):
        from repro.runtime.sharding import choose_serve_mesh
        dp, tp = choose_serve_mesh(get_config("qwen3-8b"))
        assert tp <= 4

    def test_decode_cache_seq_rule(self):
        """the mapper's Eq.1 cache decision (HC2 iteration 1)."""
        from repro.configs import SHAPES
        from tests.test_sharding import prod_plan
        _, plan = prod_plan("nemotron-4-340b", "decode_32k")
        assert plan.act_rules["cache_seq"] == "model"
        assert plan.kv_mode == "replicated"
        _, plan2 = prod_plan("gemma3-27b", "decode_32k")
        assert plan2.act_rules["cache_seq"] is None       # no win: kv%tp==0


class TestTriangularPrefill:
    def test_matches_flash(self):
        import jax.numpy as jnp
        from repro.models.attention import (chunked_attention,
                                            triangular_attention)
        B, S, G, R, D = 2, 256, 2, 2, 16
        q = jax.random.normal(jax.random.key(0), (B, S, G, R, D)) * 0.5
        k = jax.random.normal(jax.random.key(1), (B, S, G, D)) * 0.5
        v = jax.random.normal(jax.random.key(2), (B, S, G, D)) * 0.5
        ref = chunked_attention(q, k, v, causal=True, chunk=64)
        got = triangular_attention(q, k, v, chunk=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5)

    def test_prefill_path_with_flag(self):
        cfg = dataclasses.replace(get_config("qwen3-8b").reduced(),
                                  dtype="float32")
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 32), 0,
                                  cfg.vocab_size)
        ref = m.forward(params, {"tokens": toks})[0]
        ctx = ShardCtx(flags={"triangular_causal": True})
        got = m.forward(params, {"tokens": toks}, ctx=ctx)[0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-3, rtol=1e-3)
