"""The paper's central experiment at framework scale: train the same model
under the four mapping policies and compare the runtime-resolved plans.

naive  = lws-1 analogue  (microbatch of 1 sequence, minimal blocks)
fixed  = lws-32 analogue (constant microbatch/block sizes)
auto   = Eq. 1           (resolved from hardware + workload at runtime)
tuned  = Eq. 1 refined + memoized by repro.tuner (mesh tier: clean
         fallback to auto — no cost model there)

    PYTHONPATH=src python examples/mapping_policies.py
"""

import time

from repro.configs import SHAPES, get_config
from repro.core.mapper import MappingPolicy
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import resolve_microbatches
from repro.launch.train import train
from repro.runtime import sharding as shd

# --- the mesh-tier decision for a production cell -------------------------
cfg = get_config("qwen3-8b")
import jax
mesh = make_local_mesh(1, 1)
plan = shd.resolve_plan(cfg, mesh, SHAPES["train_4k"])
for pol in MappingPolicy:
    mb = resolve_microbatches(cfg, SHAPES["train_4k"], plan, policy=pol)
    print(f"{pol.value:5s}: per-device batch={mb.per_device_batch} "
          f"microbatches={mb.num_microbatches} ({mb.regime.value})")

# --- and the same policies training end-to-end ----------------------------
print()
for pol in MappingPolicy:
    t0 = time.perf_counter()
    run = train("smollm-135m", steps=10, global_batch=8, seq_len=64,
                policy=pol, verbose=False)
    print(f"{pol.value:5s}: 10 steps in {time.perf_counter()-t0:5.1f}s, "
          f"final loss {run.losses[-1]:.3f}")
