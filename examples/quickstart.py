"""Quickstart — the paper's technique in 30 lines.

Resolve Eq. 1 (lws = gws / hp) at runtime for a kernel and hardware,
simulate the four mapping policies, and run the real Pallas kernel with
the auto-resolved BlockSpec — then once more through the tuner dispatch
layer, whose second call is a pure cache hit.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (MappingPolicy, detect, plan_vector_blocks,
                        resolve_lws, simulate_policy)
from repro.core.hw import VortexParams
from repro.core.workload import vecadd as vecadd_workload
from repro.kernels.vecadd import vecadd_pallas

# --- 1. the paper's Eq. 1 on its own hardware model -----------------------
w = vecadd_workload(4096)
cfg = VortexParams(cores=4, warps=8, threads=16)           # 4c8w16t
print(f"kernel gws={w.gws}, hp={cfg.hp} -> Eq.1 lws={resolve_lws(w.gws, cfg.hp)}")
for pol in ("naive", "fixed", "auto", "tuned"):
    r = simulate_policy(w, cfg, pol)
    print(f"  {pol:5s}: lws={r.lws:4d} calls={r.calls:3d} "
          f"cycles={r.cycles:7d} ({r.regime.value})")

# --- 2. the same decision driving a real Pallas kernel --------------------
hw = detect()                 # runtime hardware introspection
plan = plan_vector_blocks(w, hw, MappingPolicy.AUTO)
print(f"\nTPU-tier plan: block={plan.block_elems} grid={plan.grid} "
      f"({plan.regime.value}, vmem={plan.vmem_bytes/1e3:.0f}KB)")
x = jnp.arange(w.gws, dtype=jnp.float32)
y = 2.0 * x
out = vecadd_pallas(x, y, hw=hw, plan=plan, interpret=True)
assert jnp.allclose(out, 3.0 * x)
print("pallas vecadd with auto-resolved BlockSpec: OK")

# --- 3. the tuned dispatch layer: refine once, cache-hit forever ----------
from repro.tuner import TuningCache, tuned_call

cache = TuningCache(path=None)          # pass a path to persist across runs
out = tuned_call("vecadd", x, y, hw=hw, cache=cache, interpret=True)  # cold
out = tuned_call("vecadd", x, y, hw=hw, cache=cache, interpret=True)  # warm
assert jnp.allclose(out, 3.0 * x)
s = cache.stats
print(f"tuner dispatch: {s.misses} miss ({s.refine_probes} refine probes), "
      f"{s.hits} hit (0 probes)")
