"""End-to-end training example: smollm-135m with the full substrate —
runtime-resolved mapping, ZeRO-1 AdamW, checkpoints, a mid-run injected
failure, and automatic restart.

    PYTHONPATH=src python examples/train_smollm.py            # reduced (CI)
    PYTHONPATH=src python examples/train_smollm.py --full     # full 135M
"""

import argparse
import tempfile

import numpy as np

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="train the full 135M config (slow on CPU)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    steps = args.steps or (200 if args.full else 60)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        run = train(
            "smollm-135m",
            steps=steps,
            global_batch=8,
            seq_len=128,
            reduced=not args.full,
            ckpt_dir=ckpt_dir,
            save_every=20,
            fail_at=(steps // 2,),      # injected node failure mid-run
        )
    first, last = np.mean(run.losses[:5]), np.mean(run.losses[-5:])
    print(f"\nloss {first:.3f} -> {last:.3f}; survived "
          f"{run.restarts} injected failure(s)")
    assert last < first, "training failed to learn"


if __name__ == "__main__":
    main()
