"""Continuous-batching serving example: queue a small mixed request load
through the ``repro.serve`` engine and print per-request latency plus the
pool's throughput — runnable in reduced mode on CPU.

The engine admits ragged prompts into a 2-slot decode pool, recycles
slots as requests finish, and resolves each shape bucket's kernel plans
through the runtime tuner (zero-probe once the bucket is warm).  The
resolved plans are EXECUTED end to end, not just recorded: each prompt
prefills in tuned-tile-sized CHUNKS interleaved with decode ticks
(``prefill_chunk="auto"``), the pool bucket's cache block parameterizes
the decode sweep, and — since the KV pool is physically paged by
default — the decode sweep consumes each row's block table directly
(the fused ``paged_decode_attention`` read at the router's tuned
``block_s``), so slot recycling re-points block tables instead of
copying cache rows.

The run also closes the runtime loop LIVE: a ``RetuneController``
(``retune="inline"``) A/B-trials plan candidates on real decode ticks
and hot-swaps the bucket's plan only when the candidate measures
faster — demonstrated below by proposing an alternative paged-decode
block mid-run (docs/SERVING.md#closing-the-runtime-loop).

The run is traced end to end through ``repro.obs``: every prefill chunk
and decode tick lands as a span carrying its bucket key and executed
plan, and the trace is written as a Perfetto/Chrome JSON you can open
at https://ui.perfetto.dev (see docs/OBSERVABILITY.md).

    PYTHONPATH=src python examples/serve_smollm.py
"""

import os

import numpy as np

from repro.obs import Tracer, write_trace
from repro.serve import RetuneConfig, ServeEngine

rng = np.random.default_rng(0)
tracer = Tracer()
engine = ServeEngine("smollm-135m", slots=2, max_len=128, reduced=True,
                     tracer=tracer, prefill_chunk="auto",
                     retune=RetuneConfig(mode="inline", min_samples=4,
                                         trial_ticks=3, cooldown_ticks=16))

reqs = []
for i, (plen, out_len) in enumerate([(5, 12), (12, 6), (3, 10), (20, 4),
                                     (9, 8), (15, 6)]):
    prompt = list(rng.integers(1, 500, size=plen))
    # stagger arrivals: the scheduler holds future requests, the engine
    # fast-forwards idle time, and slots recycle mid-decode
    reqs.append(engine.submit(prompt, max_new_tokens=out_len,
                              arrival=0.05 * i))


def on_complete(req, now):
    # after the first completion the pool bucket is warm (incumbent
    # evidence banked): propose an alternative paged-decode block — the
    # controller trials it on real ticks and keeps whichever is faster
    if req.rid == 0 and not engine.retune.stats.proposals:
        plan = engine.router.resolve(engine.router.bucket(engine.pool.kv_len))
        cand = 1 if plan.paged_decode_block != 1 else 2
        engine.retune.propose(engine.pool.kv_len, "paged_decode", cand)


report = engine.run(on_complete=on_complete)
s = report.summary

for r in reqs:
    rec = engine.metrics.records[r.rid]
    out = report.outputs[r.rid]
    print(f"req{r.rid}: prompt[{r.prompt_len:2d}] -> "
          f"generated={out[r.prompt_len:]} "
          f"(ttft {rec.ttft * 1e3:7.1f} ms)")

print(f"\n{s.n_completed}/{s.n_requests} requests, "
      f"{s.output_tokens} tokens @ {s.tokens_per_s:.1f} tok/s, "
      f"ttft p50/p95 {s.ttft_p50_s * 1e3:.1f}/{s.ttft_p95_s * 1e3:.1f} ms, "
      f"pool utilization {s.utilization:.2f}")
print(f"compiled decode shapes: {report.compiled_decode_shapes}, "
      f"prefill chunk shapes: {report.compiled_chunk_shapes}, "
      f"router: {report.router_stats}")
for d in engine.retune.decisions:
    print(f"retune: {d.kernel}@{d.bucket} {d.incumbent} -> {d.candidate} "
          f"{'ADOPTED' if d.adopted else 'reverted'} ({d.reason}, "
          f"{d.incumbent_s * 1e3:.2f} vs {d.candidate_s * 1e3:.2f} ms)")

os.makedirs("out", exist_ok=True)
trace_path = write_trace(tracer, os.path.join("out", "serve-smollm-trace.json"))
print(f"trace: {len(tracer.spans())} spans -> {trace_path} "
      f"(open at ui.perfetto.dev, or run "
      f"`PYTHONPATH=src python tools/trace_view.py {trace_path}`)")
