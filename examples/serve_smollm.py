"""Batched serving example: prefill + greedy decode over a mixed batch of
prompts with ragged lengths (continuous-batching style pool).

    PYTHONPATH=src python examples/serve_smollm.py
"""

import numpy as np

from repro.launch.serve import serve_batch

rng = np.random.default_rng(0)
prompts = [list(rng.integers(1, 500, size=n)) for n in (5, 12, 3, 20)]
stats = serve_batch("smollm-135m", prompts, max_new_tokens=12)
for i, out in enumerate(stats.outputs):
    print(f"req{i}: prompt={out[:len(prompts[i])]} -> "
          f"generated={out[len(prompts[i]):]}")
